//! The simulation engine.
//!
//! §Perf — the two hot structures of the simulation loop:
//!
//! * **Persistent forecast ring-arena + incremental selection state**
//!   ([`crate::selection::ring`], [`crate::selection::incr`]): the
//!   engine owns one [`ForecastRing`] and one [`IncrSelState`] across
//!   the whole run. After every executed round it re-anchors both
//!   (forecasts re-issued at round start, as the paper's server does);
//!   during consecutive idle (wait) polls it *advances* them by one slot
//!   — evict column t, append column t+d_max at the same issue anchor,
//!   patch the integer liveness counters and the per-domain/per-client
//!   reach structures of dirty domains. A FULLY DARK idle poll is
//!   **O(D)**: the σ refresh, the spare_now refresh, the ring's spare
//!   appends and the quick eligibility gate all skip per-client work
//!   (see the respective §Perf notes in the loop below). Strategies see
//!   the window as a borrowed [`FcView`] in the [`SelectionContext`];
//!   nothing is copied per select(). Under `ErrorLevel::Perfect` the
//!   anchoring is unobservable (forecast = actual regardless of issue
//!   time); under `Realistic` it means idle-period re-polls reuse the
//!   forecast issued at the start of the idle stretch rather than
//!   re-issuing every simulated minute — which matches how forecast
//!   vendors actually behave and is what makes the incremental advance
//!   byte-identical to a fresh build (see the ring docs).
//! * **Parallel round execution**: within one step, power attribution is
//!   independent across domains (a selected client belongs to exactly one
//!   domain), so `execute_round` computes every domain's water-filling
//!   grants in a fork-join (`util::par`, reused per-worker scratch) and
//!   then applies them — progress, energy metering, loss accounting —
//!   serially in ascending (domain, slot) order. The apply order and all
//!   f64 arithmetic are identical to the serial path, so metrics and
//!   model state are bit-identical whether or not the fan-out engages
//!   (`par_domains_min` + `par_slots_min` gate it on domain count AND
//!   work; tests force both paths and compare). The per-step
//!   `active`/`reqs`/grant buffers are hoisted out of the step loop and
//!   refilled in place on both paths.
//! * **Shard-parallel local training** (`fl` module docs): the backend is
//!   a `&self` read-mostly core, and each client's mutable train state
//!   (local params, data cursor, step counter) lives in an engine-owned
//!   [`ClientTrainState`]. Per step, the serial apply phase only
//!   *schedules* whole batches (one [`TrainJob`] per slot that earned
//!   them); the jobs — independent by construction, every job owns its
//!   client's state exclusively — then run through
//!   `TrainBackend::train_shard`, which `Sync` backends fan out across
//!   `util::par` workers. Job stats feed the loss accounting back in
//!   ascending slot order, so `MetricsLog`, the energy meter and the
//!   aggregated global model are bit-identical between the serial and
//!   sharded train paths (tests and the endtoend bench gate enforce
//!   this). Aggregation reads participant params straight out of the
//!   client states (no per-round model copies), and total train steps
//!   are a deterministic per-client reduction (`Simulation::steps_executed`)
//!   instead of a shared mutable counter.
//!
//! §Robustness — round execution is event-driven by default
//! ([`ExecMode::Fsm`]): each round runs through the coordinator state
//! machine ([`crate::coordinator::fsm`]) with liveness (churn windows,
//! chaos faults), update submission, and the round deadline all
//! delivered as epoch-tagged events from a deterministic queue
//! ([`crate::coordinator::events`]). Stale-token updates are rejected
//! and metered (`MetricsLog::rejected_updates`), never aggregated;
//! malformed decisions are rejected at the FSM boundary with a
//! structured [`crate::coordinator::fsm::DecisionError`] instead of a
//! panic. The historical batch loop survives as [`ExecMode::Legacy`] —
//! the bit-for-bit oracle: with no chaos injected, the FSM path
//! executes the identical float-op sequence (same grant computation,
//! same serial apply order, same quorum checkpoint), so `MetricsLog`,
//! the energy meter, and the global model are bitwise equal between
//! the two modes (tests below and the `benches/endtoend.rs` gate).
//! Chaos ([`crate::sim::chaos`]) requires the FSM path.
//!
//! §Durability — setting [`Simulation::durable`] turns the FSM path
//! into a crash-tolerant coordinator: every round decision and applied
//! event goes through a write-ahead journal
//! ([`crate::coordinator::journal`]) and a full-state snapshot is cut
//! at every `snapshot_every`-th round boundary. A chaos `crash_prob`
//! draw (or a real process death) aborts the run mid-step;
//! [`Simulation::resume_from`] loads the latest valid snapshot,
//! verifies the journal by replaying it through a scratch round FSM,
//! truncates the journal back to the snapshot's mark, and continues —
//! bit-identical to an uninterrupted run in `MetricsLog`, the final
//! global model, the step totals, and the journal bytes themselves
//! (re-executed rounds re-append the exact records the crash lost).

use std::path::{Path, PathBuf};

use anyhow::{anyhow, bail, Context, Result};

use crate::client::ClientInfo;
use crate::coordinator::events::{ClientEvent, EventQueue};
use crate::coordinator::fsm::{self, EventOutcome, RoundFsm};
use crate::coordinator::journal::{self, Journal, JournalRecord};
use crate::energy::{attribute_power, EnergyMeter, PowerDomain, PowerRequest};
use crate::fl::{fedavg_weights, AggMode, ClientTrainState, TrainBackend, TrainJob, TreeAggregator};
use crate::metrics::{EvalRecord, MetricsLog, RoundRecord};
use crate::selection::incr::IncrSelState;
use crate::selection::oort::UtilityTracker;
use crate::selection::ring::{FcSource, FcView, ForecastRing};
use crate::selection::{ClientRoundState, SelectionContext, SelectionDecision, Strategy};
use crate::trace::forecast::{ErrorLevel, SeriesForecaster};
use crate::util::fsx;
use crate::util::json::{num, obj, parse_u64_hex, s as jstr, u64_hex, Json};
use crate::util::obs::{self, Ctr, Hist};
use crate::util::par;
use crate::util::par::thresholds;
use crate::util::rng::Rng;

use super::chaos::{ChaosSpec, CrashFault};

/// Which round-execution path the engine uses.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum ExecMode {
    /// The historical batch loop — kept as the bit-for-bit oracle for
    /// the FSM path. Cannot express chaos faults.
    Legacy,
    /// Event-driven execution through the coordinator state machine
    /// (the default). With no chaos, bitwise-equal to `Legacy`.
    Fsm,
}

#[derive(Clone, Debug)]
pub struct SimConfig {
    pub step_minutes: f64,
    /// total simulated steps (paper: 7 days = 10080 one-minute steps)
    pub horizon: usize,
    /// clients selected per round (n)
    pub n_per_round: usize,
    /// max round duration in steps (d_max)
    pub d_max: usize,
    /// evaluate the global model every this many rounds
    pub eval_every: usize,
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            step_minutes: 1.0,
            horizon: 7 * 24 * 60,
            n_per_round: 10,
            d_max: 60,
            eval_every: 5,
            seed: 0,
        }
    }
}

/// Durable-coordinator configuration: where the write-ahead journal and
/// the snapshot checkpoints live, and how often snapshots are cut.
/// Requires [`ExecMode::Fsm`] (the journal vocabulary IS the event
/// vocabulary).
#[derive(Clone, Debug, PartialEq, Eq)]
pub struct DurableConfig {
    /// checkpoint directory (`journal.wal` + `snap_<round>.json`)
    pub dir: PathBuf,
    /// cut a snapshot every this many executed rounds (>= 1); the
    /// cadence is part of the journal's byte stream (snapshot marks),
    /// so a resume must use the same value as the original run
    pub snapshot_every: usize,
}

impl DurableConfig {
    pub fn new(dir: impl Into<PathBuf>) -> Self {
        DurableConfig { dir: dir.into(), snapshot_every: 5 }
    }

    pub fn journal_path(&self) -> PathBuf {
        self.dir.join("journal.wal")
    }

    pub fn snapshot_path(&self, round: usize) -> PathBuf {
        self.dir.join(format!("snap_{round}.json"))
    }
}

/// Snapshot schema tag; bumped on any layout change so a resume never
/// misreads an old checkpoint.
const SNAPSHOT_VERSION: &str = "fedzero-snapshot-v1";

/// f32 params travel as their u32 bit patterns (exact integers ≤ 2^32,
/// losslessly representable in a JSON f64) — immune to any float
/// formatting concern, including negative zero.
fn f32_bits_arr(v: &[f32]) -> Json {
    Json::Arr(v.iter().map(|x| num(x.to_bits() as f64)).collect())
}

fn parse_f32_bits_arr(j: &Json, what: &str) -> Result<Vec<f32>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("snapshot {what} is not an array"))?
        .iter()
        .map(|x| {
            x.as_f64()
                .filter(|v| v.fract() == 0.0 && *v >= 0.0 && *v <= u32::MAX as f64)
                .map(|v| f32::from_bits(v as u32))
                .ok_or_else(|| anyhow!("snapshot {what} holds a non-u32 entry"))
        })
        .collect()
}

/// f64 tallies (energy, losses) are non-negative sums whose shortest-
/// roundtrip text form reparses exactly — they travel as plain numbers.
fn f64_arr(v: &[f64]) -> Json {
    Json::Arr(v.iter().map(|&x| num(x)).collect())
}

fn parse_f64_arr(j: &Json, what: &str) -> Result<Vec<f64>> {
    j.as_arr()
        .ok_or_else(|| anyhow!("snapshot {what} is not an array"))?
        .iter()
        .map(|x| x.as_f64().ok_or_else(|| anyhow!("snapshot {what} holds a non-number")))
        .collect()
}

fn snap_usize(j: &Json, key: &str) -> Result<usize> {
    j.get(key)
        .and_then(|v| v.as_usize())
        .ok_or_else(|| anyhow!("snapshot missing {key}"))
}

fn snap_u64(j: &Json, key: &str) -> Result<u64> {
    parse_u64_hex(j.get(key).ok_or_else(|| anyhow!("snapshot missing {key}"))?)
        .map_err(|e| anyhow!("snapshot {key}: {e}"))
}

/// Outcome of one executed round.
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    pub duration: usize,
    /// clients that reached m_min (their updates were aggregated)
    pub participants: Vec<usize>,
    /// clients whose work was discarded (selected, did not reach m_min)
    pub stragglers: Vec<usize>,
    pub total_batches: f64,
    pub energy_wh: f64,
    /// the stragglers' share of `energy_wh` — spent on discarded work
    pub wasted_wh: f64,
    /// the round closed on its deadline/horizon with fewer than
    /// `n_required` updates (instead of on its quorum)
    pub timed_out: bool,
}

/// Everything needed to simulate one experiment configuration.
pub struct Simulation<'a, B: TrainBackend> {
    pub cfg: SimConfig,
    pub clients: Vec<ClientInfo>,
    pub domains: Vec<PowerDomain>,
    /// actual utilisation per client per step ([0,1]); spare capacity is
    /// m_c · (1 − util)
    pub load_actual: Vec<Vec<f64>>,
    /// spare-capacity forecasters per client (over the spare series, in
    /// batches/step); `ErrorLevel::Unavailable` means "assume full m_c"
    pub load_fc: Vec<SeriesForecaster>,
    pub load_fc_level: ErrorLevel,
    /// read-mostly backend core (`fl` module docs); all per-client
    /// mutation goes through `train_states`
    pub backend: &'a B,
    pub strategy: &'a mut dyn Strategy,
    /// fan the per-domain round-execution loop out across threads once a
    /// round spans at least this many domains AND selects at least
    /// `par_slots_min` clients — both gates, because thread spawn/join
    /// costs more than water-filling a handful of slots (identical
    /// results either way; tests pin these to 1 / usize::MAX to force
    /// both paths)
    pub par_domains_min: usize,
    /// minimum selected-client count before the per-domain fan-out
    /// engages (see `par_domains_min`)
    pub par_slots_min: usize,
    /// per-client outage windows `[start, end)` from the scenario churn
    /// model; empty (the default and the paper's setting) = every client
    /// always online. An offline client is excluded from the active set
    /// before power requests are built, so it receives no energy and no
    /// batches for the step. Selection stays churn-blind (the server
    /// cannot forecast outages); a client that drops mid-round stalls
    /// and, if it misses m_min, is discarded as a straggler.
    pub outages: Vec<Vec<(usize, usize)>>,
    /// which round-execution path to use (default [`ExecMode::Fsm`];
    /// the legacy loop is kept as the bitwise oracle)
    pub exec: ExecMode,
    /// optional fault injection (FSM path only): per-round dropout /
    /// stale-update / slow-client schedules, seeded pure draws
    pub chaos: Option<ChaosSpec>,
    /// the coordinator round state machine — persistent so the epoch
    /// counter is monotone across rounds (stale fencing)
    pub fsm: RoundFsm,
    /// the deterministic event queue — persistent so delayed updates
    /// can surface (and be rejected) after their round ended
    pub events: EventQueue,
    // --- state ---
    pub states: Vec<ClientRoundState>,
    /// persistent per-client train state (local params, data cursor,
    /// step counter); `take`n by the slot during an executed round and
    /// returned before aggregation, so a `None` here would mean a client
    /// was selected into two concurrent rounds (impossible: rounds are
    /// sequential)
    pub train_states: Vec<Option<ClientTrainState<B::Cursor>>>,
    pub utility: UtilityTracker,
    pub meter: EnergyMeter,
    pub metrics: MetricsLog,
    pub rng: Rng,
    /// wall-clock spent inside strategy.select (overhead accounting)
    pub select_time: std::time::Duration,
    /// the global model after `run` finishes (equality fixture for the
    /// serial-vs-sharded train-path tests and the bench gate)
    pub final_global: Vec<f32>,
    /// aggregation schedule: hierarchical per-domain tree (default) or
    /// the serial flat oracle — bitwise identical (`fl::tree` docs)
    pub agg: AggMode,
    /// the two-tier aggregator; persistent so its CSR/partial arenas are
    /// reused across rounds (allocation-free steady state)
    pub tree: TreeAggregator,
    /// domain shards whose last in-epoch update landed before round
    /// close, across all FSM rounds (eager sub-aggregation visibility)
    pub shard_completions: u64,
    /// durable-coordinator configuration (FSM mode only): when set,
    /// `run` journals every decision/event, cuts periodic snapshots,
    /// and `resume_from` can continue a crashed run bit-exactly
    pub durable: Option<DurableConfig>,
    /// open write-ahead journal while a durable run is in flight
    journal: Option<Journal>,
    /// the seeded coordinator-death step (chaos `crash_prob` draw);
    /// `resume_from` disarms it — a crash fires once per process life
    crash_at: Option<usize>,
}

/// Actual spare capacity of client `i` at step `t` (batches/step) — free
/// function so the parallel round-execution closures can capture plain
/// slices instead of the whole (non-Sync) simulation.
fn spare_actual_raw(
    clients: &[ClientInfo],
    load_actual: &[Vec<f64>],
    i: usize,
    t: usize,
) -> f64 {
    let util = load_actual
        .get(i)
        .and_then(|v| v.get(t))
        .copied()
        .unwrap_or(1.0);
    clients[i].capacity() * (1.0 - util)
}

/// Is client `i` online at step `t` per its outage windows? Windows are
/// sorted, disjoint `[start, end)` ranges from the scenario churn model
/// (`crate::scenario::churn`); an empty outage table (the legacy paper
/// scenarios) means every client is always online — and, because the
/// check only ever REMOVES slots from the active set, leaves the float
/// sequence of every grant computation untouched.
fn online_at(outages: &[Vec<(usize, usize)>], i: usize, t: usize) -> bool {
    match outages.get(i) {
        None => true,
        Some(ws) => !ws.iter().any(|&(start, end)| start <= t && t < end),
    }
}

/// The engine's forecast source for the ring: domain energy through each
/// domain's forecaster, client spare through the load forecasters,
/// pre-clamped to capacity (`ErrorLevel::Unavailable` = assume full m_c).
struct EngineFcSource<'a> {
    domains: &'a [PowerDomain],
    clients: &'a [ClientInfo],
    load_fc: &'a [SeriesForecaster],
    level: ErrorLevel,
}

impl FcSource for EngineFcSource<'_> {
    fn n_domains(&self) -> usize {
        self.domains.len()
    }

    fn n_clients(&self) -> usize {
        self.clients.len()
    }

    fn energy_at(&self, t0: usize, t: usize, p: usize) -> f64 {
        self.domains[p].forecast_energy_wh(t0, t)
    }

    fn spare_at(&self, t0: usize, t: usize, i: usize) -> f64 {
        let cap = self.clients[i].capacity();
        match self.level {
            ErrorLevel::Unavailable => cap,
            _ => self.load_fc[i].forecast(t0, t).clamp(0.0, cap),
        }
    }
}

/// One step of one domain's round execution, compute phase only (pure):
/// filter the still-active slots, build their power requests from the
/// *pre-step* progress snapshot, water-fill the domain's actual energy,
/// and emit `(slot, batch_steps)` grants. Domains never share slots, so
/// the snapshot equals the live value and parallel == serial, bit for
/// bit. The caller applies grants (progress/meter/training) serially.
///
/// Liveness comes either from the outage-window scan (`liveness:
/// None`, the legacy path) or from per-slot flags maintained by the
/// round state machine (`Some` — the FSM path, where churn AND chaos
/// both feed the same depth counter). `slow` optionally scales a
/// slot's effective compute capacity (chaos slow-client faults); the
/// no-fault paths pass `None`, leaving the float sequence untouched.
#[allow(clippy::too_many_arguments)]
fn compute_domain_grants(
    clients: &[ClientInfo],
    domains: &[PowerDomain],
    load_actual: &[Vec<f64>],
    outages: &[Vec<(usize, usize)>],
    liveness: Option<&[bool]>,
    slow: Option<&[f64]>,
    sel: &[usize],
    progress: &[f64],
    unconstrained: bool,
    dom: usize,
    slots: &[usize],
    tt: usize,
    active: &mut Vec<usize>,
    reqs: &mut Vec<PowerRequest>,
    out: &mut Vec<(usize, f64)>,
) {
    out.clear();
    active.clear();
    // an offline (churned-out or chaos-dropped) client is dropped
    // BEFORE requests are built, so it is granted neither energy nor
    // batches this step — on either the constrained or the
    // unconstrained (Upper Bound) path
    active.extend(
        slots
            .iter()
            .copied()
            .filter(|&s| {
                progress[s] < clients[sel[s]].m_max - 1e-9
                    && match liveness {
                        Some(lv) => lv[s],
                        None => online_at(outages, sel[s], tt),
                    }
            }),
    );
    if active.is_empty() {
        return;
    }
    if unconstrained {
        // Upper bound: full capacity, grid energy
        for &s in active.iter() {
            let c = &clients[sel[s]];
            let cap = match slow {
                Some(sl) => c.capacity() * sl[s],
                None => c.capacity(),
            };
            out.push((s, cap.min(c.m_max - progress[s])));
        }
        return;
    }
    reqs.clear();
    reqs.extend(active.iter().map(|&s| {
        let c = &clients[sel[s]];
        let delta = c.delta();
        let spare = match slow {
            Some(sl) => spare_actual_raw(clients, load_actual, sel[s], tt) * sl[s],
            None => spare_actual_raw(clients, load_actual, sel[s], tt),
        };
        PowerRequest {
            need_min_wh: delta * (c.m_min - progress[s]).max(0.0),
            need_max_wh: delta * (c.m_max - progress[s]).max(0.0),
            usable_wh: delta * spare.min(c.m_max - progress[s]).max(0.0),
        }
    }));
    let available = domains[dom].energy_wh(tt);
    if available.is_infinite() {
        // unlimited domain: everyone gets their cap
        for (&s, r) in active.iter().zip(reqs.iter()) {
            out.push((s, r.usable_wh.min(r.need_max_wh) / clients[sel[s]].delta()));
        }
    } else {
        let alloc = attribute_power(available, reqs);
        out.extend(
            active
                .iter()
                .zip(&alloc)
                .map(|(&s, &wh)| (s, wh / clients[sel[s]].delta())),
        );
    }
}

impl<'a, B: TrainBackend> Simulation<'a, B> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        cfg: SimConfig,
        clients: Vec<ClientInfo>,
        domains: Vec<PowerDomain>,
        load_actual: Vec<Vec<f64>>,
        load_fc: Vec<SeriesForecaster>,
        load_fc_level: ErrorLevel,
        backend: &'a B,
        strategy: &'a mut dyn Strategy,
    ) -> Self {
        let n_clients = clients.len();
        let n_domains = domains.len();
        let seed = cfg.seed;
        let step_minutes = cfg.step_minutes;
        let train_states = (0..n_clients)
            .map(|i| Some(ClientTrainState::new(backend.make_cursor(i))))
            .collect();
        Simulation {
            cfg,
            clients,
            domains,
            load_actual,
            load_fc,
            load_fc_level,
            backend,
            strategy,
            par_domains_min: thresholds::ROUND_DOMAINS,
            par_slots_min: thresholds::ROUND_SLOTS,
            outages: Vec::new(),
            exec: ExecMode::Fsm,
            chaos: None,
            fsm: RoundFsm::new(),
            events: EventQueue::new(),
            states: vec![ClientRoundState::default(); n_clients],
            train_states,
            utility: UtilityTracker::new(n_clients),
            meter: EnergyMeter::new(n_clients, n_domains),
            metrics: MetricsLog::new(step_minutes),
            rng: Rng::new(seed ^ 0x51D),
            select_time: std::time::Duration::ZERO,
            final_global: Vec::new(),
            agg: AggMode::Tree,
            tree: TreeAggregator::new(),
            shard_completions: 0,
            durable: None,
            journal: None,
            crash_at: None,
        }
    }

    /// Total train-step executions across all clients: a deterministic
    /// reduction over the per-client state counters in client-index
    /// order — no shared mutable counter to contend on (or for a backend
    /// to forget to maintain).
    pub fn steps_executed(&self) -> u64 {
        self.train_states
            .iter()
            .map(|st| st.as_ref().map_or(0, |s| s.steps))
            .sum()
    }

    /// actual spare capacity of client `i` at step `t` (batches/step)
    fn spare_actual(&self, i: usize, t: usize) -> f64 {
        spare_actual_raw(&self.clients, &self.load_actual, i, t)
    }

    /// Deliver every queued event due at or before `now` to the state
    /// machine. Between rounds the machine is `Idle`, so the only
    /// event that *does* anything here is a late `UpdateSubmitted` —
    /// rejected as stale and metered. No-op when the queue is empty
    /// (every no-chaos run). Durable runs journal each event at
    /// application time, fenced or not, so replay reproduces the
    /// rejection accounting exactly.
    fn drain_due_events(&mut self, now: usize) -> Result<()> {
        while let Some(ev) = self.events.pop_due(now) {
            if let Some(j) = self.journal.as_mut() {
                j.append(&JournalRecord::Event { at: now, ev })?;
            }
            if self.fsm.apply(&ev) == EventOutcome::StaleUpdate {
                obs::add(Ctr::ChaosStaleRejected, 1);
                self.metrics.rejected_updates += 1;
            }
        }
        Ok(())
    }

    /// Run the full simulation: returns the metrics log (also stored).
    ///
    /// With [`Simulation::durable`] set, the run starts a fresh journal
    /// (truncating any prior one in the directory — use
    /// [`Simulation::resume_from`] to continue instead) and cuts an
    /// initial snapshot before the first step. A chaos `crash_prob`
    /// draw aborts with a downcastable [`CrashFault`] at the drawn
    /// timestep; the journal and snapshots written up to that point are
    /// exactly what `resume_from` needs.
    pub fn run(&mut self) -> Result<()> {
        if self.exec == ExecMode::Legacy && self.chaos.is_some() {
            bail!(
                "chaos fault injection requires ExecMode::Fsm — the legacy \
                 loop has no event vocabulary to express faults"
            );
        }
        if self.durable.is_some() && self.exec != ExecMode::Fsm {
            bail!(
                "the durable coordinator (journal + snapshots) requires \
                 ExecMode::Fsm — only event-driven rounds are journalable"
            );
        }
        let global = self.backend.init_params(self.cfg.seed as i32)?;
        // one Bernoulli draw per run on a dedicated stream: arming it
        // cannot move any other seeded draw (sim::chaos docs)
        self.crash_at = self
            .chaos
            .as_ref()
            .and_then(|c| c.draw_crash(self.cfg.seed, self.cfg.horizon));
        if let Some(d) = self.durable.clone() {
            if d.snapshot_every == 0 {
                bail!("durable snapshot_every must be >= 1");
            }
            fsx::create_dir_all(&d.dir)?;
            self.journal = Some(Journal::create(&d.journal_path())?);
            // round-0 snapshot: a crash at any step ≥ 1 always has a
            // checkpoint to fall back to
            self.write_snapshot(&d, &global, 0, 0)?;
        }
        self.run_loop(global, 0, 0)
    }

    /// Continue a crashed durable run from `dir`: load the latest valid
    /// snapshot, verify the surviving journal by replaying it through a
    /// scratch round FSM, truncate the journal back to that snapshot's
    /// mark, and re-enter the run loop with the crash fault disarmed.
    /// Everything downstream — selection, training, aggregation,
    /// metrics, and the re-appended journal records — is bit-identical
    /// to the uninterrupted run.
    pub fn resume_from(&mut self, dir: &Path) -> Result<()> {
        if self.exec != ExecMode::Fsm {
            bail!(
                "the durable coordinator (journal + snapshots) requires \
                 ExecMode::Fsm — only event-driven rounds are journalable"
            );
        }
        let d = match &self.durable {
            Some(d) if d.dir == dir => d.clone(),
            Some(d) => bail!(
                "resume_from({}) conflicts with the configured durable dir {}",
                dir.display(),
                d.dir.display()
            ),
            None => {
                let d = DurableConfig::new(dir);
                self.durable = Some(d.clone());
                d
            }
        };
        if d.snapshot_every == 0 {
            bail!("durable snapshot_every must be >= 1");
        }
        // latest snapshot that parses and carries the right version tag
        let mut best: Option<(usize, Json)> = None;
        let entries = std::fs::read_dir(&d.dir)
            .with_context(|| format!("listing checkpoint dir {}", d.dir.display()))?;
        for entry in entries {
            let entry = entry
                .with_context(|| format!("listing checkpoint dir {}", d.dir.display()))?;
            let name = entry.file_name().to_string_lossy().into_owned();
            let Some(stem) =
                name.strip_prefix("snap_").and_then(|r| r.strip_suffix(".json"))
            else {
                continue;
            };
            let Ok(round) = stem.parse::<usize>() else { continue };
            if best.as_ref().map_or(false, |(r, _)| *r >= round) {
                continue;
            }
            let Ok(text) = fsx::read_to_string(&entry.path()) else { continue };
            let Ok(doc) = Json::parse(&text) else { continue };
            if doc.get("version").and_then(|v| v.as_str()) != Some(SNAPSHOT_VERSION) {
                continue;
            }
            best = Some((round, doc));
        }
        let (round, doc) = best.ok_or_else(|| {
            anyhow!("no valid snapshot checkpoint in {}", d.dir.display())
        })?;
        let (global, t, snap_round) = self.restore_snapshot(&doc)?;
        if snap_round != round {
            bail!(
                "snapshot {} claims round {snap_round} (file name says {round})",
                d.snapshot_path(round).display()
            );
        }
        // journal: verify the durable prefix replays cleanly, then cut
        // it back to the loaded snapshot's mark so re-executed rounds
        // re-append their records (byte-identical to the untorn log)
        let (mut wal, records) = match Journal::open(&d.journal_path()) {
            Ok(x) => x,
            // a lost journal is survivable: the snapshot alone resumes
            // the run, and a fresh mark restarts the log from here
            Err(_) => (Journal::create(&d.journal_path())?, Vec::new()),
        };
        journal::verify_replay(&records).with_context(|| {
            format!("journal {} failed replay verification", d.journal_path().display())
        })?;
        if !wal.truncate_to_mark(round)? {
            wal.reset()?;
            wal.append(&JournalRecord::SnapshotMark { round, t })?;
        }
        self.journal = Some(wal);
        // a chaos crash models one process death; the resumed process
        // does not re-die at the same drawn step
        self.crash_at = None;
        self.run_loop(global, t, round)
    }

    /// Cut a snapshot checkpoint at an idle round boundary: atomic file
    /// write, then the journal mark that resume truncates back to.
    fn write_snapshot(
        &mut self,
        d: &DurableConfig,
        global: &[f32],
        t: usize,
        round: usize,
    ) -> Result<()> {
        let doc = self.snapshot_json(global, t, round)?;
        fsx::write_atomic(&d.snapshot_path(round), doc.to_string_pretty().as_bytes())?;
        if let Some(j) = self.journal.as_mut() {
            j.append(&JournalRecord::SnapshotMark { round, t })?;
        }
        Ok(())
    }

    /// Serialise every piece of state the run loop carries across round
    /// boundaries. The config echo lets resume refuse a mismatched
    /// reconstruction instead of silently diverging.
    fn snapshot_json(&self, global: &[f32], t: usize, round: usize) -> Result<Json> {
        let config = obj(vec![
            ("seed", u64_hex(self.cfg.seed)),
            ("horizon", num(self.cfg.horizon as f64)),
            ("step_minutes", num(self.cfg.step_minutes)),
            ("n_per_round", num(self.cfg.n_per_round as f64)),
            ("d_max", num(self.cfg.d_max as f64)),
            ("eval_every", num(self.cfg.eval_every as f64)),
            ("n_clients", num(self.clients.len() as f64)),
            ("n_domains", num(self.domains.len() as f64)),
            ("param_count", num(self.backend.param_count() as f64)),
            ("strategy", jstr(self.strategy.name())),
        ]);
        let (rng_s, rng_spare) = self.rng.state();
        let rng = obj(vec![
            ("s", Json::Arr(rng_s.iter().map(|&w| u64_hex(w)).collect())),
            // the spare gaussian travels as f64 bits: it is the one
            // snapshotted float that can be negative (±0.0 included)
            (
                "gauss_spare",
                match rng_spare {
                    Some(x) => u64_hex(x.to_bits()),
                    None => Json::Null,
                },
            ),
        ]);
        let states = Json::Arr(
            self.states
                .iter()
                .map(|s| {
                    obj(vec![
                        ("participation", num(s.participation as f64)),
                        ("sigma", num(s.sigma)),
                        ("blocked", Json::Bool(s.blocked)),
                    ])
                })
                .collect(),
        );
        let mut trains = Vec::with_capacity(self.train_states.len());
        for (i, st) in self.train_states.iter().enumerate() {
            let st = st
                .as_ref()
                .ok_or_else(|| anyhow!("client {i} train state missing at snapshot"))?;
            let cursor = self.backend.cursor_to_json(&st.cursor).ok_or_else(|| {
                anyhow!(
                    "durable runs need cursor checkpointing, which this \
                     backend does not support"
                )
            })?;
            trains.push(obj(vec![
                ("params", f32_bits_arr(&st.params)),
                ("steps", u64_hex(st.steps)),
                ("cursor", cursor),
            ]));
        }
        let utility = Json::Arr(
            self.utility
                .snapshot()
                .iter()
                .map(|l| match l {
                    Some(x) => num(*x),
                    None => Json::Null,
                })
                .collect(),
        );
        let (m_client, m_domain, m_round, m_total) = self.meter.snapshot();
        let meter = obj(vec![
            ("per_client_wh", f64_arr(m_client)),
            ("per_domain_wh", f64_arr(m_domain)),
            ("per_round_wh", f64_arr(m_round)),
            ("total_wh", num(m_total)),
        ]);
        let events = Json::Arr(
            self.events
                .to_sorted_vec()
                .into_iter()
                .map(|(at, ev)| {
                    obj(vec![
                        ("at", num(at as f64)),
                        ("ev", journal::event_to_json(&ev)),
                    ])
                })
                .collect(),
        );
        let mut pairs = vec![
            ("version", jstr(SNAPSHOT_VERSION)),
            ("config", config),
            ("t", num(t as f64)),
            ("round", num(round as f64)),
            ("global_bits", f32_bits_arr(global)),
            ("rng", rng),
            ("fsm_epoch", u64_hex(self.fsm.epoch())),
            ("shard_completions", u64_hex(self.shard_completions)),
            ("events", events),
            ("states", states),
            ("train", Json::Arr(trains)),
            ("utility", utility),
            ("meter", meter),
            ("metrics", self.metrics.snapshot_json()),
        ];
        if let Some(st) = self.strategy.snapshot_state() {
            pairs.push(("strategy_state", st));
        }
        Ok(obj(pairs))
    }

    /// Rebuild every engine-owned state field from a snapshot document.
    /// Returns `(global params, t, round)` for the run loop.
    fn restore_snapshot(&mut self, doc: &Json) -> Result<(Vec<f32>, usize, usize)> {
        let cfgj = doc.get("config").ok_or_else(|| anyhow!("snapshot missing config"))?;
        let expect = |key: &str, want: usize| -> Result<()> {
            let got = snap_usize(cfgj, key)?;
            if got != want {
                bail!("snapshot config mismatch: {key} is {got}, this run has {want}");
            }
            Ok(())
        };
        let seed = snap_u64(cfgj, "seed")?;
        if seed != self.cfg.seed {
            bail!(
                "snapshot config mismatch: seed is {seed:#x}, this run has {:#x}",
                self.cfg.seed
            );
        }
        expect("horizon", self.cfg.horizon)?;
        expect("n_per_round", self.cfg.n_per_round)?;
        expect("d_max", self.cfg.d_max)?;
        expect("eval_every", self.cfg.eval_every)?;
        expect("n_clients", self.clients.len())?;
        expect("n_domains", self.domains.len())?;
        expect("param_count", self.backend.param_count())?;
        let sm = cfgj
            .get("step_minutes")
            .and_then(|v| v.as_f64())
            .ok_or_else(|| anyhow!("snapshot missing step_minutes"))?;
        if sm.to_bits() != self.cfg.step_minutes.to_bits() {
            bail!(
                "snapshot config mismatch: step_minutes is {sm}, this run has {}",
                self.cfg.step_minutes
            );
        }
        let strat = cfgj
            .get("strategy")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("snapshot missing strategy"))?;
        if strat != self.strategy.name() {
            bail!(
                "snapshot config mismatch: strategy is {strat:?}, this run \
                 has {:?}",
                self.strategy.name()
            );
        }

        let t = snap_usize(doc, "t")?;
        let round = snap_usize(doc, "round")?;
        let global = parse_f32_bits_arr(
            doc.get("global_bits").ok_or_else(|| anyhow!("snapshot missing global_bits"))?,
            "global_bits",
        )?;
        if global.len() != self.backend.param_count() {
            bail!("snapshot global model has {} params, backend expects {}",
                global.len(), self.backend.param_count());
        }

        let rngj = doc.get("rng").ok_or_else(|| anyhow!("snapshot missing rng"))?;
        let words = rngj
            .get("s")
            .and_then(|v| v.as_arr())
            .filter(|a| a.len() == 4)
            .ok_or_else(|| anyhow!("snapshot rng.s must be 4 words"))?;
        let mut s = [0u64; 4];
        for (i, w) in words.iter().enumerate() {
            s[i] = parse_u64_hex(w).map_err(|e| anyhow!("snapshot rng.s[{i}]: {e}"))?;
        }
        let spare = match rngj.get("gauss_spare") {
            None | Some(Json::Null) => None,
            Some(v) => Some(f64::from_bits(
                parse_u64_hex(v).map_err(|e| anyhow!("snapshot gauss_spare: {e}"))?,
            )),
        };
        self.rng = Rng::from_state(s, spare);

        self.fsm = RoundFsm::new();
        self.fsm.restore_epoch(snap_u64(doc, "fsm_epoch")?);
        self.shard_completions = snap_u64(doc, "shard_completions")?;

        self.events.clear();
        for (i, e) in doc
            .get("events")
            .and_then(|v| v.as_arr())
            .ok_or_else(|| anyhow!("snapshot missing events"))?
            .iter()
            .enumerate()
        {
            let at = snap_usize(e, "at")?;
            let ev = journal::event_from_json(
                e.get("ev").ok_or_else(|| anyhow!("snapshot event {i} missing ev"))?,
            )
            .map_err(|err| anyhow!("snapshot event {i}: {err}"))?;
            self.events.push(at, ev);
        }

        let statesj = doc
            .get("states")
            .and_then(|v| v.as_arr())
            .filter(|a| a.len() == self.clients.len())
            .ok_or_else(|| anyhow!("snapshot states must cover every client"))?;
        self.states = statesj
            .iter()
            .map(|sj| {
                Ok(ClientRoundState {
                    participation: snap_usize(sj, "participation")?,
                    sigma: sj
                        .get("sigma")
                        .and_then(|v| v.as_f64())
                        .ok_or_else(|| anyhow!("snapshot state missing sigma"))?,
                    blocked: sj
                        .get("blocked")
                        .and_then(|v| v.as_bool())
                        .ok_or_else(|| anyhow!("snapshot state missing blocked"))?,
                })
            })
            .collect::<Result<Vec<_>>>()?;

        let trainj = doc
            .get("train")
            .and_then(|v| v.as_arr())
            .filter(|a| a.len() == self.clients.len())
            .ok_or_else(|| anyhow!("snapshot train states must cover every client"))?;
        let mut train_states = Vec::with_capacity(trainj.len());
        for (i, tj) in trainj.iter().enumerate() {
            let cursor = self.backend.cursor_from_json(
                i,
                tj.get("cursor")
                    .ok_or_else(|| anyhow!("snapshot train state {i} missing cursor"))?,
            )?;
            let mut st = ClientTrainState::new(cursor);
            st.params = parse_f32_bits_arr(
                tj.get("params")
                    .ok_or_else(|| anyhow!("snapshot train state {i} missing params"))?,
                "train params",
            )?;
            st.steps = snap_u64(tj, "steps")?;
            train_states.push(Some(st));
        }
        self.train_states = train_states;

        let utilj = doc
            .get("utility")
            .and_then(|v| v.as_arr())
            .filter(|a| a.len() == self.clients.len())
            .ok_or_else(|| anyhow!("snapshot utility must cover every client"))?;
        self.utility = UtilityTracker::restore(
            utilj
                .iter()
                .map(|v| match v {
                    Json::Null => Ok(None),
                    other => other
                        .as_f64()
                        .map(Some)
                        .ok_or_else(|| anyhow!("snapshot utility holds a non-number")),
                })
                .collect::<Result<Vec<_>>>()?,
        );

        let meterj = doc.get("meter").ok_or_else(|| anyhow!("snapshot missing meter"))?;
        self.meter = EnergyMeter::restore(
            parse_f64_arr(
                meterj.get("per_client_wh").ok_or_else(|| anyhow!("snapshot meter missing per_client_wh"))?,
                "meter.per_client_wh",
            )?,
            parse_f64_arr(
                meterj.get("per_domain_wh").ok_or_else(|| anyhow!("snapshot meter missing per_domain_wh"))?,
                "meter.per_domain_wh",
            )?,
            parse_f64_arr(
                meterj.get("per_round_wh").ok_or_else(|| anyhow!("snapshot meter missing per_round_wh"))?,
                "meter.per_round_wh",
            )?,
            meterj
                .get("total_wh")
                .and_then(|v| v.as_f64())
                .ok_or_else(|| anyhow!("snapshot meter missing total_wh"))?,
        );

        self.metrics = MetricsLog::from_snapshot_json(
            doc.get("metrics").ok_or_else(|| anyhow!("snapshot missing metrics"))?,
        )
        .map_err(|e| anyhow!("snapshot metrics: {e}"))?;

        if let Some(st) = doc.get("strategy_state") {
            self.strategy.restore_state(st)?;
        }
        Ok((global, t, round))
    }

    /// The simulation loop proper, entered at `(t, round)` — `(0, 0)`
    /// for a fresh run, the loaded checkpoint for a resume. Everything
    /// the loop consumes beyond its arguments is engine state that
    /// `restore_snapshot` reconstructs exactly; the loop-local caches
    /// (forecast ring, incremental selection state, idle-poll flag) are
    /// rebuilt deterministically at the first iteration, which at a
    /// round boundary is bit-identical to the uninterrupted run.
    fn run_loop(&mut self, global: Vec<f32>, t: usize, round: usize) -> Result<()> {
        let mut global = global;
        let mut t = t;
        let mut round = round;
        // §Perf: the forecast ring-arena AND the incremental selection
        // state persist across the whole run — see the module docs.
        // `last_was_wait` decides advance (same anchor, O(D) when dark)
        // vs rebuild (re-issue at t, O((C+D)·d_max)).
        let mut ring = ForecastRing::new();
        let mut incr = IncrSelState::new();
        let wants_fc = self.strategy.needs_forecasts();
        let wants_spare = self.strategy.needs_spare_now();
        let use_incr = wants_fc && self.strategy.uses_selection_state();
        let mut last_was_wait = false;
        let mut samples: Vec<usize> = Vec::with_capacity(self.clients.len());
        let mut spare_now: Vec<f64> = Vec::with_capacity(self.clients.len());
        while t < self.cfg.horizon {
            // armed chaos crash: the coordinator dies between rounds,
            // leaving journal + snapshots as the only surviving state
            if let Some(ca) = self.crash_at {
                if t >= ca {
                    obs::add(Ctr::ChaosCrashes, 1);
                    return Err(CrashFault { at: ca }.into());
                }
            }
            // late updates from closed rounds surface here (the queue
            // persists across rounds) and are fenced off by their stale
            // epoch token — rejected and metered, never aggregated
            if !self.events.is_empty() {
                self.drain_due_events(t)?;
            }
            // §Perf: σ/participation/blocklist only mutate when a round
            // executes, and the utility refresh is a pure function of
            // them — consecutive idle polls skip the O(C) refresh
            // entirely (bit-identical: it would recompute the same σ).
            // This invariant is also what keeps the incremental state's
            // liveness snapshot valid across advances.
            if !last_was_wait {
                samples.clear();
                samples.extend(self.clients.iter().map(|c| c.num_samples()));
                self.utility.refresh(&mut self.states, &samples);
            }

            // §Perf: the window is only maintained for strategies that
            // read forecasts (FedZero, *-fc); Random/Oort/UpperBound
            // never pay for it. The incremental selection state rides
            // along only for strategies that consume it (FedZero).
            if wants_fc {
                let src = EngineFcSource {
                    domains: &self.domains,
                    clients: &self.clients,
                    load_fc: &self.load_fc,
                    level: self.load_fc_level,
                };
                if ring.is_built() && last_was_wait && t == ring.window_start() + 1 {
                    if use_incr {
                        incr.advance(&mut ring, &src);
                    } else {
                        ring.advance(&src);
                    }
                    obs::add(Ctr::EngineRingAdvances, 1);
                } else if !ring.is_built() || ring.window_start() != t {
                    ring.rebuild(&src, t, self.cfg.d_max);
                    if use_incr {
                        incr.rebuild(&self.clients, &self.states, ring.view());
                    }
                    obs::add(Ctr::EngineRingRebuilds, 1);
                }
            }
            // §Perf: the O(C) current-spare refresh only runs for
            // strategies that read it (needs_spare_now) — FedZero's
            // filters are purely forecast-driven, so its dark idle polls
            // stay O(D)
            if wants_spare {
                spare_now.clear();
                spare_now
                    .extend((0..self.clients.len()).map(|i| self.spare_actual(i, t)));
            }
            let decision = {
                let ctx = SelectionContext {
                    now: t,
                    n: self.cfg.n_per_round,
                    d_max: self.cfg.d_max,
                    clients: &self.clients,
                    states: &self.states,
                    domains: &self.domains,
                    fc: if wants_fc { ring.view() } else { FcView::empty() },
                    incr: if use_incr && incr.is_built() { Some(&incr) } else { None },
                    spare_now: &spare_now,
                };
                let t0 = std::time::Instant::now();
                let d = self.strategy.select(&ctx, &mut self.rng);
                let dt = t0.elapsed();
                self.select_time += dt;
                obs::span_at("select", t0, dt, Hist::SelectNs);
                d
            };
            if decision.wait {
                obs::add(Ctr::EngineIdleSteps, 1);
                last_was_wait = true;
                t += 1;
                continue;
            }
            last_was_wait = false;

            // FSM boundary: malformed decisions (duplicate or
            // out-of-range clients) are rejected with a structured
            // error and metered — the historical path panicked deep
            // inside execute_round
            if let Err(e) = fsm::validate_decision(&decision, self.clients.len()) {
                self.metrics.rejected_decisions += 1;
                return Err(anyhow::Error::new(e));
            }

            let round_span = obs::span("round", Hist::RoundNs);
            obs::add(Ctr::EngineRounds, 1);
            let (out, losses) = match self.exec {
                ExecMode::Legacy => self.execute_round(&decision, t, &global)?,
                ExecMode::Fsm => self.execute_round_fsm(&decision, round, t, &global)?,
            };

            // aggregate participant updates (weights = sample counts)
            // through the two-tier domain aggregator — `self.agg` picks
            // the parallel tree schedule or the serial flat oracle, both
            // bitwise identical (`fl::tree` docs) — reading the params
            // straight out of the returned client states: no per-round
            // model copies. An empty-participant round degrades to a
            // no-op aggregation.
            let mut agg_domains = 0usize;
            if !out.participants.is_empty() {
                let _agg_span = obs::span("aggregate", Hist::AggregateNs);
                let weights = fedavg_weights(
                    &out.participants
                        .iter()
                        .map(|&c| self.clients[c].num_samples())
                        .collect::<Vec<_>>(),
                );
                let part_domains: Vec<usize> = out
                    .participants
                    .iter()
                    .map(|&c| self.clients[c].domain)
                    .collect();
                let updates: Vec<&[f32]> = out
                    .participants
                    .iter()
                    .map(|&c| {
                        self.train_states[c]
                            .as_ref()
                            .expect("round returned its states")
                            .params
                            .as_slice()
                    })
                    .collect();
                self.tree.aggregate_into(
                    self.agg,
                    &part_domains,
                    &updates,
                    &weights,
                    &mut global,
                )?;
                agg_domains = self.tree.groups();
            }
            if self.exec == ExecMode::Fsm {
                self.fsm.round_end(); // Aggregating → RoundEnd
            }

            // bookkeeping: utility, participation, blocklist
            for (&c, &loss) in out.participants.iter().zip(&losses) {
                self.states[c].participation += 1;
                self.utility.update(c, loss, self.clients[c].num_samples());
            }
            self.strategy.on_round_end(
                &out.participants,
                &mut self.states,
                &mut self.rng,
            );

            let mean_loss = if losses.is_empty() {
                0.0
            } else {
                losses.iter().sum::<f64>() / losses.len() as f64
            };
            let duration = out.duration;
            // the selected/participant vectors move straight into the
            // record — they used to be cloned twice per round
            self.metrics.rounds.push(RoundRecord {
                round,
                start_step: t,
                duration_steps: duration,
                selected: decision.clients,
                participants: out.participants,
                batches: out.total_batches,
                energy_wh: out.energy_wh,
                wasted_wh: out.wasted_wh,
                mean_loss,
                timed_out: out.timed_out,
                agg_domains,
            });
            drop(round_span);
            if self.exec == ExecMode::Fsm {
                self.fsm.finish(); // RoundEnd → Idle
            }

            t += duration.max(1);
            round += 1;

            if round % self.cfg.eval_every == 0 || t >= self.cfg.horizon {
                let _eval_span = obs::span("eval", Hist::EvalNs);
                obs::add(Ctr::EngineEvals, 1);
                let (acc, loss) = self.backend.evaluate(&global)?;
                self.metrics.evals.push(EvalRecord {
                    round,
                    step: t,
                    accuracy: acc,
                    loss,
                    cumulative_kwh: self.meter.total_kwh(),
                });
            }

            // periodic checkpoint at the idle round boundary (round has
            // already advanced, so round 0's initial snapshot never
            // collides with the cadence)
            if let Some(d) = self.durable.clone() {
                if round % d.snapshot_every == 0 {
                    self.write_snapshot(&d, &global, t, round)?;
                    obs::add(Ctr::EngineSnapshots, 1);
                }
            }
        }
        // backstop: if the final round's duration jumped t past both
        // the crash step and the horizon, the crash still fires — an
        // armed fault always kills the run, so crash_prob = 1.0 is a
        // guarantee, not a likelihood
        if let Some(ca) = self.crash_at {
            obs::add(Ctr::ChaosCrashes, 1);
            return Err(CrashFault { at: ca }.into());
        }
        // updates still in flight when the horizon ends are stale by
        // definition — drain and meter them so waste accounting is
        // complete (no-op without chaos: the queue is empty)
        self.drain_due_events(usize::MAX)?;
        self.final_global = global;
        Ok(())
    }

    /// Execute one round starting at `t0`. Returns (outcome, participant
    /// mean losses aligned with outcome.participants); the participants'
    /// updated params stay in `self.train_states` for the caller to
    /// aggregate.
    fn execute_round(
        &mut self,
        decision: &SelectionDecision,
        t0: usize,
        global: &[f32],
    ) -> Result<(RoundOutcome, Vec<f64>)> {
        self.meter.begin_round();
        let sel = &decision.clients;
        let k = sel.len();
        // pull the selected clients' persistent train states for the
        // round; params reset to the global snapshot in place (reusing
        // their capacity — the historical code cloned `global` k times)
        let mut round_states: Vec<ClientTrainState<B::Cursor>> =
            Vec::with_capacity(k);
        for &c in sel.iter() {
            // decisions are validated at the FSM boundary before any
            // round executes (distinct, in-range clients), so the
            // state is always present — the historical code panicked
            // here on duplicates
            let mut st = self.train_states[c]
                .take()
                .expect("decision validated: clients are distinct and in range");
            st.reset_params(global);
            round_states.push(st);
        }
        let mut progress = vec![0.0f64; k]; // fractional batch credit
        let mut executed = vec![0usize; k]; // whole batches run
        let mut n_new = vec![0usize; k]; // whole batches earned this step
        let mut loss_acc = vec![0.0f64; k];
        let mut loss_batches = vec![0usize; k];
        let mut slot_wh = vec![0.0f64; k]; // per-slot energy (waste split)
        // incremental end-condition: progress is monotone within a round,
        // so count each slot once when it first crosses m_min instead of
        // rescanning all k slots every step. Slots with m_min <= 0 count
        // from step one, exactly like the historical rescan did.
        let mut reached = vec![false; k];
        let mut done = 0usize;
        for s in 0..k {
            if 0.0 >= self.clients[sel[s]].m_min - 1e-9 {
                reached[s] = true;
                done += 1;
            }
        }
        // §Perf (ROADMAP "per-step job vec"): ONE index-based job arena
        // hoisted to round scope — jobs reference slot indices into
        // `round_states` instead of borrowing them, so the buffer is
        // refilled in place every step and training steps allocate
        // nothing in steady state
        let mut jobs: Vec<TrainJob> = Vec::with_capacity(k);
        let mut duration = 0usize;

        // group selected clients by domain once per round (ascending
        // domain order — the serial apply order)
        let mut by_domain: std::collections::BTreeMap<usize, Vec<usize>> =
            std::collections::BTreeMap::new();
        for (slot, &c) in sel.iter().enumerate() {
            by_domain
                .entry(self.clients[c].domain)
                .or_default()
                .push(slot);
        }
        let groups: Vec<(usize, Vec<usize>)> = by_domain.into_iter().collect();

        // §Perf: all per-step buffers hoisted out of the step loop —
        // serial steps are allocation-free in steady state (the historical
        // code rebuilt `active`/`reqs`/`batch_steps` per domain per step)
        let mut grants: Vec<Vec<(usize, f64)>> = vec![Vec::new(); groups.len()];
        let mut active: Vec<usize> = Vec::new();
        let mut reqs: Vec<PowerRequest> = Vec::new();

        let round_cap = decision.max_duration.max(1).min(self.cfg.d_max);
        for step in 0..round_cap {
            let tt = t0 + step;
            if tt >= self.cfg.horizon {
                break;
            }
            duration = step + 1;

            // compute phase: per-domain water-filling, parallel at scale.
            // The fan-out gates on BOTH domain count and selected-slot
            // count (thread spawn/join dwarfs a few slots' float work).
            // Both paths refill the hoisted `grants` rows in place, so
            // steady-state steps allocate nothing either way. Closures
            // capture plain slices only (the backend/strategy fields are
            // not Sync) and read the pre-step `progress` snapshot.
            {
                let _grant_span = obs::span("grant", Hist::GrantNs);
                let clients = &self.clients;
                let domains = &self.domains;
                let load_actual = &self.load_actual;
                let outages: &[Vec<(usize, usize)>] = &self.outages;
                let progress_ro: &[f64] = &progress;
                let unconstrained = decision.unconstrained;
                let use_par = groups.len() >= self.par_domains_min
                    && k >= self.par_slots_min
                    && par::threads() > 1;
                if use_par {
                    let groups = &groups;
                    // stolen fill: domain slot counts are skewed, so an
                    // idle worker steals queued domain rows instead of
                    // waiting behind one giant domain
                    par::steal::steal_fill_rows_scratch(
                        &mut grants,
                        1,
                        0,
                        0,
                        || (Vec::new(), Vec::new()),
                        |g,
                         row: &mut [Vec<(usize, f64)>],
                         (active, reqs): &mut (Vec<usize>, Vec<PowerRequest>)| {
                            compute_domain_grants(
                                clients, domains, load_actual, outages, None,
                                None, sel, progress_ro, unconstrained,
                                groups[g].0, &groups[g].1, tt, active, reqs,
                                &mut row[0],
                            );
                        },
                    );
                } else {
                    for (g, (dom, slots)) in groups.iter().enumerate() {
                        compute_domain_grants(
                            clients, domains, load_actual, outages, None, None,
                            sel, progress_ro, unconstrained, *dom, slots, tt,
                            &mut active, &mut reqs, &mut grants[g],
                        );
                    }
                }
            }

            // apply/meter phase: serial, ascending (domain, slot) order —
            // the exact historical sequence for progress and energy
            // metering. Training is only *scheduled* here: the whole
            // batches each slot earned this step go into `n_new`.
            for v in n_new.iter_mut() {
                *v = 0;
            }
            for (g, (dom, _slots)) in groups.iter().enumerate() {
                for &(s, b) in &grants[g] {
                    if b <= 0.0 {
                        continue;
                    }
                    progress[s] += b;
                    let wh = b * self.clients[sel[s]].delta();
                    self.meter.record(sel[s], *dom, wh);
                    slot_wh[s] += wh;
                    let want = progress[s].floor() as usize;
                    if want > executed[s] {
                        n_new[s] = want - executed[s];
                        executed[s] = want;
                    }
                    if !reached[s]
                        && progress[s] >= self.clients[sel[s]].m_min - 1e-9
                    {
                        reached[s] = true;
                        done += 1;
                    }
                }
            }

            // train phase: one job per slot that earned whole batches,
            // in ascending slot order (the strictly-increasing-slot
            // contract of `train_shard`). Each job exclusively owns its
            // slot's state, so the backend may fan the jobs out across
            // workers — per-slot params/stats are bit-identical to the
            // serial order either way, and the loss accounting below
            // stays serial in slot order.
            jobs.clear();
            for s in 0..k {
                if n_new[s] > 0 {
                    jobs.push(TrainJob::new(sel[s], n_new[s], s));
                }
            }
            if !jobs.is_empty() {
                let _train_span = obs::span("train", Hist::TrainNs);
                self.backend.train_shard(global, &mut jobs, &mut round_states)?;
            }
            for j in &jobs {
                loss_acc[j.slot] += j.stats.mean_loss * j.n_batches as f64;
                loss_batches[j.slot] += j.n_batches;
            }

            // end condition: n_required clients reached their minimum
            // (incremental `done` counter, see above)
            if done >= decision.n_required {
                break;
            }
        }

        let mut participants = Vec::new();
        let mut stragglers = Vec::new();
        let mut losses = Vec::new();
        let mut wasted_wh = 0.0f64;
        for s in 0..k {
            if reached[s] && executed[s] > 0 {
                participants.push(sel[s]);
                losses.push(if loss_batches[s] > 0 {
                    loss_acc[s] / loss_batches[s] as f64
                } else {
                    0.0
                });
            } else {
                stragglers.push(sel[s]);
                wasted_wh += slot_wh[s];
            }
        }
        let total_batches: f64 = progress.iter().sum();
        let energy_wh = self.meter.round_wh(self.meter.rounds() - 1);
        // return the states; participants' params are read by the caller
        // for aggregation before the next round resets them
        for (s, st) in round_states.into_iter().enumerate() {
            self.train_states[sel[s]] = Some(st);
        }
        Ok((
            RoundOutcome {
                duration,
                participants,
                stragglers,
                total_batches,
                energy_wh,
                wasted_wh,
                timed_out: done < decision.n_required,
            },
            losses,
        ))
    }

    /// Execute one round through the coordinator state machine
    /// ([`crate::coordinator::fsm`]): churn windows and chaos faults
    /// arrive as epoch-tagged `Dropout`/`Rejoin` events, a slot
    /// crossing `m_min` *submits* an `UpdateSubmitted` event (possibly
    /// delayed by chaos), and the round deadline is a `Timeout` event
    /// scheduled at `t0 + max_duration`. With no chaos injected the
    /// float-op sequence — grant computation, serial (domain, slot)
    /// apply order, the quorum checkpoint after the train phase — is
    /// identical to [`Self::execute_round`], which the bitwise
    /// equality tests and the endtoend bench gate pin down.
    fn execute_round_fsm(
        &mut self,
        decision: &SelectionDecision,
        round: usize,
        t0: usize,
        global: &[f32],
    ) -> Result<(RoundOutcome, Vec<f64>)> {
        self.meter.begin_round();
        let sel = &decision.clients;
        let k = sel.len();
        let round_cap = decision.max_duration.max(1).min(self.cfg.d_max);

        // Idle → Selecting: validate (already done upstream; the FSM
        // boundary re-checks its own invariant), mint the epoch, and
        // schedule the CheckIns plus the round Timeout
        self.fsm
            .begin_round(decision, self.clients.len(), t0, round_cap, &mut self.events)
            .map_err(anyhow::Error::new)?;
        let epoch = self.fsm.epoch();
        if let Some(j) = self.journal.as_mut() {
            j.append(&JournalRecord::RoundStart {
                round,
                epoch,
                t0,
                round_cap,
                n_clients: self.clients.len(),
                clients: sel.clone(),
                n_required: decision.n_required,
                unconstrained: decision.unconstrained,
            })?;
        }
        // declare each slot's energy domain so the FSM tracks when a
        // domain shard's last in-epoch update lands — the eager
        // sub-aggregation point of the two-tier tree (`fl::tree` docs)
        let domain_of_slot: Vec<usize> =
            sel.iter().map(|&c| self.clients[c].domain).collect();
        self.fsm.assign_domains(&domain_of_slot);

        // Translate churn windows overlapping the round span into
        // Dropout/Rejoin events (windows already open at t0 become
        // initial offline depth — the queue only carries in-round
        // transitions), and draw each slot's chaos fault plan (a pure
        // function of (seed, client, t0) — see sim::chaos).
        let mut submit_delay = vec![0usize; k];
        let mut slow = vec![1.0f64; k];
        let mut any_slow = false;
        for (s, &c) in sel.iter().enumerate() {
            if let Some(ws) = self.outages.get(c) {
                for &(start, end) in ws {
                    if end <= t0 || start >= t0 + round_cap {
                        continue;
                    }
                    if start <= t0 {
                        self.fsm.add_initial_offline(s);
                    } else {
                        self.events
                            .push(start, ClientEvent::Dropout { client: c, epoch });
                    }
                    if end < t0 + round_cap {
                        self.events.push(end, ClientEvent::Rejoin { client: c, epoch });
                    }
                }
            }
            if let Some(ch) = &self.chaos {
                let plan =
                    ch.round_plan(self.cfg.seed, c, t0, round_cap, self.cfg.step_minutes);
                if let Some((off, len)) = plan.drop_window {
                    obs::add(Ctr::ChaosDropouts, 1);
                    if off == 0 {
                        self.fsm.add_initial_offline(s);
                    } else {
                        self.events
                            .push(t0 + off, ClientEvent::Dropout { client: c, epoch });
                    }
                    let end = t0 + off + len;
                    if end < t0 + round_cap {
                        self.events.push(end, ClientEvent::Rejoin { client: c, epoch });
                    }
                }
                if plan.submit_delay > 0 {
                    obs::add(Ctr::ChaosDelays, 1);
                }
                submit_delay[s] = plan.submit_delay;
                if plan.slow < 1.0 {
                    obs::add(Ctr::ChaosSlowdowns, 1);
                    any_slow = true;
                }
                slow[s] = plan.slow;
            }
        }
        self.fsm.start_training(); // Selecting → Training

        // round-scoped numeric state, identical to the legacy loop
        let mut round_states: Vec<ClientTrainState<B::Cursor>> = Vec::with_capacity(k);
        for &c in sel.iter() {
            let mut st = self.train_states[c]
                .take()
                .expect("decision validated: clients are distinct and in range");
            st.reset_params(global);
            round_states.push(st);
        }
        let mut progress = vec![0.0f64; k];
        let mut executed = vec![0usize; k];
        let mut n_new = vec![0usize; k];
        let mut loss_acc = vec![0.0f64; k];
        let mut loss_batches = vec![0usize; k];
        let mut slot_wh = vec![0.0f64; k];
        // slots with m_min <= 0 submit an (empty) update immediately —
        // their event lands before step 0 executes, matching the
        // legacy preseed that counted them toward the quorum up front
        let mut reached = vec![false; k];
        for s in 0..k {
            if 0.0 >= self.clients[sel[s]].m_min - 1e-9 {
                reached[s] = true;
                self.events.push(
                    t0 + submit_delay[s],
                    ClientEvent::UpdateSubmitted { client: sel[s], epoch },
                );
            }
        }
        let mut jobs: Vec<TrainJob> = Vec::with_capacity(k);
        let mut duration = 0usize;

        let mut by_domain: std::collections::BTreeMap<usize, Vec<usize>> =
            std::collections::BTreeMap::new();
        for (slot, &c) in sel.iter().enumerate() {
            by_domain.entry(self.clients[c].domain).or_default().push(slot);
        }
        let groups: Vec<(usize, Vec<usize>)> = by_domain.into_iter().collect();

        let mut grants: Vec<Vec<(usize, f64)>> = vec![Vec::new(); groups.len()];
        let mut active: Vec<usize> = Vec::new();
        let mut reqs: Vec<PowerRequest> = Vec::new();
        let mut online = vec![true; k];
        let mut timeout_fired = false;

        loop {
            let tt = t0 + duration;
            // armed chaos crash inside the round: the coordinator dies
            // BEFORE this step's events are popped, so the journal ends
            // as a legal open-round prefix (RoundStart + the events
            // delivered so far) that replay verification tolerates
            if let Some(ca) = self.crash_at {
                if tt >= ca {
                    obs::add(Ctr::ChaosCrashes, 1);
                    for (s, st) in round_states.into_iter().enumerate() {
                        self.train_states[sel[s]] = Some(st);
                    }
                    return Err(CrashFault { at: ca }.into());
                }
            }
            // deliver everything due by now: liveness transitions and
            // delayed submissions land before this step's grants; a
            // due Timeout closes the round before the step executes
            // (≡ the legacy loop bound). Once the Timeout fires, the
            // rest of the queue stays put — anything still pending is
            // stale by construction and is metered after close.
            while let Some(ev) = self.events.pop_due(tt) {
                if let Some(j) = self.journal.as_mut() {
                    j.append(&JournalRecord::Event { at: tt, ev })?;
                }
                match self.fsm.apply(&ev) {
                    EventOutcome::StaleUpdate => {
                        obs::add(Ctr::ChaosStaleRejected, 1);
                        self.metrics.rejected_updates += 1;
                    }
                    EventOutcome::TimeoutFired => {
                        timeout_fired = true;
                        break;
                    }
                    _ => {}
                }
            }
            if timeout_fired || tt >= self.cfg.horizon || duration >= round_cap {
                break;
            }
            duration += 1;

            // compute phase: identical to the legacy loop except that
            // liveness comes from the state machine's depth counters
            // (boolean-identical to the window scan when chaos is off)
            for (s, o) in online.iter_mut().enumerate() {
                *o = self.fsm.online(s);
            }
            {
                let _grant_span = obs::span("grant", Hist::GrantNs);
                let clients = &self.clients;
                let domains = &self.domains;
                let load_actual = &self.load_actual;
                let outages: &[Vec<(usize, usize)>] = &self.outages;
                let progress_ro: &[f64] = &progress;
                let liveness: Option<&[bool]> = Some(&online);
                let slow_ro: Option<&[f64]> =
                    if any_slow { Some(&slow) } else { None };
                let unconstrained = decision.unconstrained;
                let use_par = groups.len() >= self.par_domains_min
                    && k >= self.par_slots_min
                    && par::threads() > 1;
                if use_par {
                    let groups = &groups;
                    // stolen fill — same skewed-domain rationale as the
                    // legacy loop above
                    par::steal::steal_fill_rows_scratch(
                        &mut grants,
                        1,
                        0,
                        0,
                        || (Vec::new(), Vec::new()),
                        |g,
                         row: &mut [Vec<(usize, f64)>],
                         (active, reqs): &mut (Vec<usize>, Vec<PowerRequest>)| {
                            compute_domain_grants(
                                clients, domains, load_actual, outages,
                                liveness, slow_ro, sel, progress_ro,
                                unconstrained, groups[g].0, &groups[g].1, tt,
                                active, reqs, &mut row[0],
                            );
                        },
                    );
                } else {
                    for (g, (dom, slots)) in groups.iter().enumerate() {
                        compute_domain_grants(
                            clients, domains, load_actual, outages, liveness,
                            slow_ro, sel, progress_ro, unconstrained, *dom,
                            slots, tt, &mut active, &mut reqs, &mut grants[g],
                        );
                    }
                }
            }

            // apply/meter phase: the exact legacy serial (domain,
            // slot) sequence; a slot crossing m_min SUBMITS its update
            // as an event (chaos may delay it past the round's close)
            for v in n_new.iter_mut() {
                *v = 0;
            }
            for (g, (dom, _slots)) in groups.iter().enumerate() {
                for &(s, b) in &grants[g] {
                    if b <= 0.0 {
                        continue;
                    }
                    progress[s] += b;
                    let wh = b * self.clients[sel[s]].delta();
                    self.meter.record(sel[s], *dom, wh);
                    slot_wh[s] += wh;
                    let want = progress[s].floor() as usize;
                    if want > executed[s] {
                        n_new[s] = want - executed[s];
                        executed[s] = want;
                    }
                    if !reached[s]
                        && progress[s] >= self.clients[sel[s]].m_min - 1e-9
                    {
                        reached[s] = true;
                        self.events.push(
                            tt + submit_delay[s],
                            ClientEvent::UpdateSubmitted { client: sel[s], epoch },
                        );
                    }
                }
            }

            // train phase: unchanged (see execute_round)
            jobs.clear();
            for s in 0..k {
                if n_new[s] > 0 {
                    jobs.push(TrainJob::new(sel[s], n_new[s], s));
                }
            }
            if !jobs.is_empty() {
                let _train_span = obs::span("train", Hist::TrainNs);
                self.backend.train_shard(global, &mut jobs, &mut round_states)?;
            }
            for j in &jobs {
                loss_acc[j.slot] += j.stats.mean_loss * j.n_batches as f64;
                loss_batches[j.slot] += j.n_batches;
            }

            // deliver this step's zero-delay submissions, then check
            // the quorum exactly where the legacy loop checks `done`
            while let Some(ev) = self.events.pop_due(tt) {
                if let Some(j) = self.journal.as_mut() {
                    j.append(&JournalRecord::Event { at: tt, ev })?;
                }
                match self.fsm.apply(&ev) {
                    EventOutcome::StaleUpdate => {
                        obs::add(Ctr::ChaosStaleRejected, 1);
                        self.metrics.rejected_updates += 1;
                    }
                    EventOutcome::TimeoutFired => {
                        timeout_fired = true;
                        break;
                    }
                    _ => {}
                }
            }
            if timeout_fired || self.fsm.quorum() {
                break;
            }
        }

        // Training → Aggregating. A round that closed with zero
        // submissions (everyone dropped, or the horizon hit first)
        // degrades to an empty participant set — no error, no panic.
        let timed_out = !self.fsm.quorum();
        self.fsm.close(timed_out);
        self.shard_completions += self.fsm.shards_complete() as u64;

        let mut participants = Vec::new();
        let mut stragglers = Vec::new();
        let mut losses = Vec::new();
        let mut wasted_wh = 0.0f64;
        for s in 0..k {
            // a participant must have SUBMITTED in time — a slot that
            // reached m_min but whose update is still in flight when
            // the round closes is a straggler, and its energy is waste
            if self.fsm.submitted(s) && executed[s] > 0 {
                participants.push(sel[s]);
                losses.push(if loss_batches[s] > 0 {
                    loss_acc[s] / loss_batches[s] as f64
                } else {
                    0.0
                });
            } else {
                stragglers.push(sel[s]);
                wasted_wh += slot_wh[s];
            }
        }
        let total_batches: f64 = progress.iter().sum();
        let energy_wh = self.meter.round_wh(self.meter.rounds() - 1);
        for (s, st) in round_states.into_iter().enumerate() {
            self.train_states[sel[s]] = Some(st);
        }
        if let Some(j) = self.journal.as_mut() {
            j.append(&JournalRecord::RoundClose {
                round,
                timed_out,
                submitted: (0..k).filter(|&s| self.fsm.submitted(s)).collect(),
                participants: participants.clone(),
            })?;
        }
        Ok((
            RoundOutcome {
                duration,
                participants,
                stragglers,
                total_batches,
                energy_wh,
                wasted_wh,
                timed_out,
            },
            losses,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::{ClientProfile, DeviceType, ModelKind};
    use crate::fl::MockBackend;
    use crate::selection::baselines::{Baseline, UpperBound};
    use crate::selection::fedzero::{FedZero, SolverKind};

    fn build(
        n_clients: usize,
        n_domains: usize,
        power_w: f64,
        horizon: usize,
    ) -> (Vec<ClientInfo>, Vec<PowerDomain>, Vec<Vec<f64>>, Vec<SeriesForecaster>)
    {
        let clients: Vec<ClientInfo> = (0..n_clients)
            .map(|i| {
                let p = ClientProfile::new(
                    DeviceType::ALL[i % 3],
                    ModelKind::Vision,
                    10,
                    1.0,
                );
                ClientInfo::new(i, i % n_domains, p, (0..60).collect(), 10)
            })
            .collect();
        let domains: Vec<PowerDomain> = (0..n_domains)
            .map(|i| {
                let series = vec![power_w; horizon];
                PowerDomain::new(
                    i,
                    "d",
                    800.0,
                    series.clone(),
                    SeriesForecaster::perfect(series),
                    1.0,
                )
            })
            .collect();
        let load: Vec<Vec<f64>> =
            (0..n_clients).map(|_| vec![0.0; horizon]).collect();
        let load_fc: Vec<SeriesForecaster> = clients
            .iter()
            .map(|c| {
                SeriesForecaster::perfect(vec![c.capacity(); horizon])
            })
            .collect();
        (clients, domains, load, load_fc)
    }

    fn run_sim(
        strategy: &mut dyn Strategy,
        power_w: f64,
    ) -> (MetricsLog, f64) {
        let (m, kwh, _, _) = run_sim_forced(strategy, power_w, 8, usize::MAX);
        (m, kwh)
    }

    /// Run the fixture with both fan-outs pinned: `par_domains_min`
    /// forces/disables the grant compute fan-out, `par_train_min` the
    /// backend train-shard fan-out. Returns (metrics, kwh, final global
    /// params, total train steps).
    fn run_sim_forced(
        strategy: &mut dyn Strategy,
        power_w: f64,
        par_domains_min: usize,
        par_train_min: usize,
    ) -> (MetricsLog, f64, Vec<f32>, u64) {
        let horizon = 600;
        let (clients, domains, load, load_fc) = build(9, 3, power_w, horizon);
        let mut backend = MockBackend::new(9, 8, 0.2, 7);
        backend.par_min_jobs = par_train_min;
        let cfg = SimConfig {
            horizon,
            n_per_round: 3,
            d_max: 30,
            eval_every: 2,
            seed: 1,
            step_minutes: 1.0,
        };
        let mut sim = Simulation::new(
            cfg,
            clients,
            domains,
            load,
            load_fc,
            ErrorLevel::Realistic,
            &backend,
            strategy,
        );
        sim.par_domains_min = par_domains_min;
        sim.par_slots_min = par_domains_min; // force both gates together
        sim.run().unwrap();
        let kwh = sim.meter.total_kwh();
        let steps = sim.steps_executed();
        let global = std::mem::take(&mut sim.final_global);
        (sim.metrics, kwh, global, steps)
    }

    #[test]
    fn fedzero_trains_and_converges_on_mock() {
        let mut fz = FedZero::new(SolverKind::Greedy);
        let (m, kwh) = run_sim(&mut fz, 800.0);
        assert!(m.rounds.len() > 5, "only {} rounds", m.rounds.len());
        assert!(m.best_accuracy() > 0.5, "acc {}", m.best_accuracy());
        assert!(kwh > 0.0);
        // energy accounting consistent between meter and metrics
        assert!((kwh - m.total_energy_kwh()).abs() < 1e-9);
    }

    #[test]
    fn all_baselines_run() {
        for mut s in [
            Baseline::random(),
            Baseline::random_over(),
            Baseline::random_fc(),
            Baseline::oort(),
            Baseline::oort_over(),
            Baseline::oort_fc(),
        ] {
            let (m, _) = run_sim(&mut s, 800.0);
            assert!(!m.rounds.is_empty(), "{} did no rounds", s.name());
        }
        let mut ub = UpperBound;
        let (m, _) = run_sim(&mut ub, 0.0); // no excess energy needed
        assert!(m.best_accuracy() > 0.5);
    }

    #[test]
    fn no_power_means_no_rounds_except_upper_bound() {
        let mut fz = FedZero::new(SolverKind::Greedy);
        let (m, kwh) = run_sim(&mut fz, 0.0);
        assert!(m.rounds.is_empty());
        assert_eq!(kwh, 0.0);
    }

    #[test]
    fn energy_budget_is_respected_per_domain_step() {
        // run with modest power and verify no round used more energy than
        // domains could provide: total kWh <= power * time
        let mut fz = FedZero::new(SolverKind::Greedy);
        let (m, kwh) = run_sim(&mut fz, 100.0);
        let horizon_h = 600.0 / 60.0;
        let max_possible_kwh = 3.0 * 100.0 * horizon_h / 1000.0;
        assert!(kwh <= max_possible_kwh + 1e-9, "{kwh} > {max_possible_kwh}");
        assert!(!m.rounds.is_empty());
    }

    #[test]
    fn over_selection_discards_stragglers() {
        // scarce energy -> with 1.3n over-selection some clients won't
        // finish; participants <= selected
        let mut s = Baseline::random_over();
        let (m, _) = run_sim(&mut s, 60.0);
        let mut saw_discard = false;
        for r in &m.rounds {
            assert!(r.participants.len() <= r.selected.len());
            if r.participants.len() < r.selected.len() {
                saw_discard = true;
            }
            // waste accounting: the stragglers' energy is a sub-share of
            // the round total, and zero when everyone finished
            assert!(r.wasted_wh >= 0.0 && r.wasted_wh <= r.energy_wh + 1e-9);
            if r.participants.len() == r.selected.len() {
                assert_eq!(r.wasted_wh, 0.0);
            }
        }
        assert!(saw_discard, "expected at least one straggler");
        assert!(m.total_wasted_kwh() > 0.0, "stragglers wasted no energy?");
    }

    #[test]
    fn offline_clients_get_no_energy_and_no_batches() {
        // the churn-model contract: a client inside an outage window is
        // granted neither energy nor training batches — here client 0 is
        // offline for the whole horizon, so it must end at exactly zero
        // despite abundant power and being selectable
        let horizon = 600;
        let (clients, domains, load, load_fc) = build(9, 3, 800.0, horizon);
        let backend = MockBackend::new(9, 8, 0.2, 7);
        let mut s = Baseline::random();
        let cfg = SimConfig {
            horizon,
            n_per_round: 3,
            d_max: 30,
            eval_every: 2,
            seed: 1,
            step_minutes: 1.0,
        };
        let mut sim = Simulation::new(
            cfg,
            clients,
            domains,
            load,
            load_fc,
            ErrorLevel::Realistic,
            &backend,
            &mut s,
        );
        let mut outages = vec![Vec::new(); 9];
        outages[0] = vec![(0, horizon)];
        outages[1] = vec![(0, 100), (300, 400)]; // partial outages
        sim.outages = outages;
        sim.run().unwrap();
        assert!(!sim.metrics.rounds.is_empty());
        assert_eq!(sim.meter.client_wh(0), 0.0, "offline client drew energy");
        assert_eq!(
            sim.train_states[0].as_ref().unwrap().steps,
            0,
            "offline client ran batches"
        );
        assert_eq!(sim.metrics.participation_counts(9)[0], 0);
        // the partially offline client can still participate while online
        // but never inside its windows: rounds fully inside an outage
        // window must not list it as a participant
        for r in &sim.metrics.rounds {
            let span = (r.start_step, r.start_step + r.duration_steps);
            let inside_outage =
                span.1 <= 100 || (span.0 >= 300 && span.1 <= 400);
            if inside_outage {
                assert!(
                    !r.participants.contains(&1),
                    "client 1 participated during an outage (round at {span:?})"
                );
            }
        }
        // the run as a whole still makes progress
        assert!(sim.meter.total_kwh() > 0.0);
    }

    #[test]
    fn empty_outage_table_changes_nothing() {
        // the churn hook must be a strict no-op when unused: a run with
        // an explicit all-online table equals the default bit for bit
        let mut a = FedZero::new(SolverKind::Greedy);
        let (m_default, kwh_default) = run_sim(&mut a, 300.0);
        let horizon = 600;
        let (clients, domains, load, load_fc) = build(9, 3, 300.0, horizon);
        let mut backend = MockBackend::new(9, 8, 0.2, 7);
        backend.par_min_jobs = usize::MAX; // mirror run_sim's fixture
        let mut fz = FedZero::new(SolverKind::Greedy);
        let cfg = SimConfig {
            horizon,
            n_per_round: 3,
            d_max: 30,
            eval_every: 2,
            seed: 1,
            step_minutes: 1.0,
        };
        let mut sim = Simulation::new(
            cfg,
            clients,
            domains,
            load,
            load_fc,
            ErrorLevel::Realistic,
            &backend,
            &mut fz,
        );
        sim.outages = vec![Vec::new(); 9]; // explicit, but all online
        sim.par_domains_min = 8; // mirror run_sim's forced gates
        sim.par_slots_min = 8;
        sim.run().unwrap();
        assert_eq!(sim.metrics, m_default);
        assert_eq!(sim.meter.total_kwh(), kwh_default);
    }

    #[test]
    fn fedzero_rounds_do_not_exceed_dmax() {
        let mut fz = FedZero::new(SolverKind::Greedy);
        let (m, _) = run_sim(&mut fz, 300.0);
        for r in &m.rounds {
            assert!(r.duration_steps <= 30);
        }
    }

    #[test]
    fn participation_is_tracked() {
        let mut fz = FedZero::new(SolverKind::Greedy);
        let (m, _) = run_sim(&mut fz, 800.0);
        let counts = m.participation_counts(9);
        assert_eq!(
            counts.iter().sum::<usize>(),
            m.rounds.iter().map(|r| r.participants.len()).sum::<usize>()
        );
    }

    #[test]
    fn parallel_round_execution_matches_serial_bitwise() {
        // same sim, forced-parallel vs forced-serial domain execution:
        // every metric (incl. f64 energy/loss values) must be identical.
        // On single-core hosts both runs take the serial path and the
        // assertion is trivially true.
        for power in [800.0, 100.0, 60.0] {
            let mut fz_par = FedZero::new(SolverKind::Greedy);
            let (m_par, kwh_par, _, _) =
                run_sim_forced(&mut fz_par, power, 1, usize::MAX);
            let mut fz_ser = FedZero::new(SolverKind::Greedy);
            let (m_ser, kwh_ser, _, _) =
                run_sim_forced(&mut fz_ser, power, usize::MAX, usize::MAX);
            assert_eq!(m_par, m_ser, "metrics diverged at power {power}");
            assert_eq!(kwh_par, kwh_ser, "energy diverged at power {power}");
        }
        // over-selection exercises straggler paths under contention
        let mut b_par = Baseline::random_over();
        let (m_par, _, _, _) = run_sim_forced(&mut b_par, 60.0, 1, usize::MAX);
        let mut b_ser = Baseline::random_over();
        let (m_ser, _, _, _) =
            run_sim_forced(&mut b_ser, 60.0, usize::MAX, usize::MAX);
        assert_eq!(m_par, m_ser);
    }

    #[test]
    fn parallel_training_matches_serial_bitwise() {
        // forced shard fan-out vs forced serial shard, with the grant
        // fan-out toggled independently: MetricsLog, energy, the FINAL
        // GLOBAL MODEL (bitwise) and the step totals must all agree.
        for power in [800.0, 100.0, 60.0] {
            let mut fz_ser = FedZero::new(SolverKind::Greedy);
            let (m_ser, kwh_ser, g_ser, steps_ser) =
                run_sim_forced(&mut fz_ser, power, usize::MAX, usize::MAX);
            for grants_min in [1usize, usize::MAX] {
                let mut fz_par = FedZero::new(SolverKind::Greedy);
                let (m_par, kwh_par, g_par, steps_par) =
                    run_sim_forced(&mut fz_par, power, grants_min, 1);
                assert_eq!(m_par, m_ser, "metrics diverged at power {power}");
                assert_eq!(kwh_par, kwh_ser, "energy diverged at power {power}");
                assert_eq!(steps_par, steps_ser, "steps diverged at {power}");
                let bits_ser: Vec<u32> =
                    g_ser.iter().map(|x| x.to_bits()).collect();
                let bits_par: Vec<u32> =
                    g_par.iter().map(|x| x.to_bits()).collect();
                assert_eq!(
                    bits_par, bits_ser,
                    "global model diverged at power {power}"
                );
            }
        }
        // straggler-heavy contention through the sharded path too
        let mut b_ser = Baseline::random_over();
        let (m_ser, _, g_ser, _) =
            run_sim_forced(&mut b_ser, 60.0, usize::MAX, usize::MAX);
        let mut b_par = Baseline::random_over();
        let (m_par, _, g_par, _) = run_sim_forced(&mut b_par, 60.0, 1, 1);
        assert_eq!(m_par, m_ser);
        assert_eq!(
            g_par.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            g_ser.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
    }

    #[test]
    fn steps_executed_counts_trained_batches() {
        let mut fz = FedZero::new(SolverKind::Greedy);
        let (m, _, _, steps) = run_sim_forced(&mut fz, 800.0, 8, usize::MAX);
        assert!(!m.rounds.is_empty());
        // every executed whole batch is one train step; batch totals in
        // the metrics are fractional credits, so steps <= ceil(batches)
        let credit: f64 = m.rounds.iter().map(|r| r.batches).sum();
        assert!(steps > 0, "no steps recorded");
        assert!(
            (steps as f64) <= credit + m.rounds.len() as f64,
            "steps {steps} exceed batch credit {credit}"
        );
    }

    // ---- robustness: FSM path, chaos engine, malformed decisions ----

    /// Run the standard 9-client/3-domain fixture with an explicit
    /// execution mode, outage table and chaos spec. Serial everywhere
    /// (both fan-out gates pinned off) so runs are comparable bit for
    /// bit across modes.
    fn run_sim_exec(
        strategy: &mut dyn Strategy,
        power_w: f64,
        exec: ExecMode,
        outages: Option<Vec<Vec<(usize, usize)>>>,
        chaos: Option<ChaosSpec>,
    ) -> (MetricsLog, f64, Vec<f32>, u64) {
        run_sim_agg(strategy, power_w, exec, outages, chaos, AggMode::Tree)
    }

    /// `run_sim_exec` plus an explicit aggregation schedule. Tree runs
    /// force the per-domain fan-out on (the 3-domain fixture is below
    /// the real `TREE_GROUPS` gate) so tree-vs-flat tests genuinely
    /// exercise the parallel leaf tier.
    fn run_sim_agg(
        strategy: &mut dyn Strategy,
        power_w: f64,
        exec: ExecMode,
        outages: Option<Vec<Vec<(usize, usize)>>>,
        chaos: Option<ChaosSpec>,
        agg: AggMode,
    ) -> (MetricsLog, f64, Vec<f32>, u64) {
        let horizon = 600;
        let (clients, domains, load, load_fc) = build(9, 3, power_w, horizon);
        let mut backend = MockBackend::new(9, 8, 0.2, 7);
        backend.par_min_jobs = usize::MAX;
        let cfg = SimConfig {
            horizon,
            n_per_round: 3,
            d_max: 30,
            eval_every: 2,
            seed: 1,
            step_minutes: 1.0,
        };
        let mut sim = Simulation::new(
            cfg,
            clients,
            domains,
            load,
            load_fc,
            ErrorLevel::Realistic,
            &backend,
            strategy,
        );
        sim.par_domains_min = usize::MAX;
        sim.par_slots_min = usize::MAX;
        sim.exec = exec;
        sim.agg = agg;
        if agg == AggMode::Tree {
            sim.tree.par_groups_min = 1;
            sim.tree.par_work_min = 0;
        }
        if let Some(o) = outages {
            sim.outages = o;
        }
        sim.chaos = chaos;
        sim.run().unwrap();
        let kwh = sim.meter.total_kwh();
        let steps = sim.steps_executed();
        let global = std::mem::take(&mut sim.final_global);
        (sim.metrics, kwh, global, steps)
    }

    /// THE determinism gate of the PR: with no chaos injected, the
    /// event-driven path must reproduce the legacy batch loop bit for
    /// bit — MetricsLog equality (every f64 energy/loss included), same
    /// meter total, same final global model bits, same step counts —
    /// across quorum-closing, over-selecting and deadline-closing
    /// strategies at abundant, constrained and scarce power.
    #[test]
    fn fsm_matches_legacy_loop_bitwise() {
        let mk: [(&str, fn() -> Box<dyn Strategy>); 3] = [
            ("fedzero", || Box::new(FedZero::new(SolverKind::Greedy))),
            ("random_over", || Box::new(Baseline::random_over())),
            ("semisync", || {
                Box::new(crate::selection::semisync::SemiSync::new(
                    FedZero::new(SolverKind::Greedy),
                    15,
                ))
            }),
        ];
        for (name, make) in mk {
            for power in [800.0, 100.0, 60.0] {
                let mut s_legacy = make();
                let (m_l, kwh_l, g_l, st_l) = run_sim_exec(
                    s_legacy.as_mut(), power, ExecMode::Legacy, None, None,
                );
                let mut s_fsm = make();
                let (m_f, kwh_f, g_f, st_f) = run_sim_exec(
                    s_fsm.as_mut(), power, ExecMode::Fsm, None, None,
                );
                assert_eq!(m_f, m_l, "{name}@{power}: metrics diverged");
                assert_eq!(kwh_f, kwh_l, "{name}@{power}: energy diverged");
                assert_eq!(st_f, st_l, "{name}@{power}: steps diverged");
                assert_eq!(
                    g_f.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    g_l.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                    "{name}@{power}: global model diverged"
                );
                // no faults → nothing may have been fenced or rejected
                assert_eq!(m_f.rejected_updates, 0);
                assert_eq!(m_f.rejected_decisions, 0);
            }
        }
    }

    /// THE hierarchical-aggregation gate: the parallel tree schedule
    /// must reproduce the serial flat oracle bit for bit — MetricsLog
    /// (agg_domains included), meter total, final global model bits and
    /// step counts — across strategies × power regimes × both exec
    /// modes. The fixture pins the tree's fan-out gates open, so the
    /// leaf tier genuinely runs parallel per-domain fills.
    #[test]
    fn tree_aggregation_matches_flat_bitwise() {
        let mk: [(&str, fn() -> Box<dyn Strategy>); 3] = [
            ("fedzero", || Box::new(FedZero::new(SolverKind::Greedy))),
            ("random_over", || Box::new(Baseline::random_over())),
            ("semisync", || {
                Box::new(crate::selection::semisync::SemiSync::new(
                    FedZero::new(SolverKind::Greedy),
                    15,
                ))
            }),
        ];
        for (name, make) in mk {
            for power in [800.0, 100.0, 60.0] {
                for exec in [ExecMode::Legacy, ExecMode::Fsm] {
                    let mut s_flat = make();
                    let (m_fl, kwh_fl, g_fl, st_fl) = run_sim_agg(
                        s_flat.as_mut(), power, exec, None, None, AggMode::Flat,
                    );
                    let mut s_tree = make();
                    let (m_tr, kwh_tr, g_tr, st_tr) = run_sim_agg(
                        s_tree.as_mut(), power, exec, None, None, AggMode::Tree,
                    );
                    assert_eq!(m_tr, m_fl, "{name}@{power}/{exec:?}: metrics diverged");
                    assert_eq!(kwh_tr, kwh_fl, "{name}@{power}/{exec:?}: energy diverged");
                    assert_eq!(st_tr, st_fl, "{name}@{power}/{exec:?}: steps diverged");
                    assert_eq!(
                        g_tr.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                        g_fl.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                        "{name}@{power}/{exec:?}: global model diverged"
                    );
                }
            }
        }
    }

    /// Tree ≡ flat must survive chaos faults: dropped and stale shard
    /// members shrink (or empty) domain shards mid-round, and the two
    /// schedules must still agree bit for bit.
    #[test]
    fn tree_aggregation_matches_flat_under_chaos() {
        let chaos = ChaosSpec {
            dropout_per_round: 0.5,
            mean_drop_min: 20.0,
            stale_prob: 0.3,
            mean_delay_min: 10.0,
            slow_prob: 0.3,
            slow_factor: 0.5,
            ..ChaosSpec::default()
        };
        for power in [800.0, 100.0] {
            let mut s_flat = Baseline::random_over();
            let (m_fl, kwh_fl, g_fl, st_fl) = run_sim_agg(
                &mut s_flat, power, ExecMode::Fsm, None,
                Some(chaos.clone()), AggMode::Flat,
            );
            let mut s_tree = Baseline::random_over();
            let (m_tr, kwh_tr, g_tr, st_tr) = run_sim_agg(
                &mut s_tree, power, ExecMode::Fsm, None,
                Some(chaos.clone()), AggMode::Tree,
            );
            assert_eq!(m_tr, m_fl, "chaos@{power}: metrics diverged");
            assert_eq!(kwh_tr, kwh_fl, "chaos@{power}: energy diverged");
            assert_eq!(st_tr, st_fl, "chaos@{power}: steps diverged");
            assert_eq!(
                g_tr.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                g_fl.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                "chaos@{power}: global model diverged"
            );
        }
    }

    /// The round records expose the shard structure: every round with
    /// participants reports 1 ≤ agg_domains ≤ min(participants, domains),
    /// and the FSM path counts completed domain shards.
    #[test]
    fn agg_domains_and_shard_completions_are_recorded() {
        let horizon = 600;
        let (clients, domains, load, load_fc) = build(9, 3, 800.0, horizon);
        let mut backend = MockBackend::new(9, 8, 0.2, 7);
        backend.par_min_jobs = usize::MAX;
        let cfg = SimConfig {
            horizon,
            n_per_round: 3,
            d_max: 30,
            eval_every: 2,
            seed: 1,
            step_minutes: 1.0,
        };
        let mut strategy = FedZero::new(SolverKind::Greedy);
        let mut sim = Simulation::new(
            cfg,
            clients,
            domains,
            load,
            load_fc,
            ErrorLevel::Realistic,
            &backend,
            &mut strategy,
        );
        sim.run().unwrap();
        assert!(!sim.metrics.rounds.is_empty());
        for r in &sim.metrics.rounds {
            if r.participants.is_empty() {
                assert_eq!(r.agg_domains, 0);
            } else {
                assert!(r.agg_domains >= 1);
                assert!(r.agg_domains <= r.participants.len().min(3));
            }
        }
        assert!(sim.tree.rounds > 0, "tree aggregator never ran");
        assert!(sim.tree.peak_arena_bytes() > 0);
        // no churn/chaos: every selected slot submits, so every round's
        // shards all complete before close
        assert!(sim.shard_completions > 0, "no shard completions recorded");
    }

    /// Mid-round churn goes through the event translation (windows →
    /// Dropout/Rejoin, open windows → initial offline depth) on the FSM
    /// path and through the direct window scan on the legacy path —
    /// they must still agree bit for bit, including a client offline
    /// for the entire horizon and outages opening mid-round.
    #[test]
    fn fsm_matches_legacy_with_mid_round_churn() {
        let mut outages = vec![Vec::new(); 9];
        outages[0] = vec![(0, 600)]; // offline the whole run
        outages[1] = vec![(0, 100), (300, 400)]; // overlaps round starts
        outages[2] = vec![(50, 80), (90, 95)]; // opens mid-round
        for power in [800.0, 100.0] {
            let mut s_legacy = Baseline::random();
            let (m_l, kwh_l, g_l, st_l) = run_sim_exec(
                &mut s_legacy,
                power,
                ExecMode::Legacy,
                Some(outages.clone()),
                None,
            );
            let mut s_fsm = Baseline::random();
            let (m_f, kwh_f, g_f, st_f) = run_sim_exec(
                &mut s_fsm,
                power,
                ExecMode::Fsm,
                Some(outages.clone()),
                None,
            );
            assert_eq!(m_f, m_l, "churn@{power}: metrics diverged");
            assert_eq!(kwh_f, kwh_l, "churn@{power}: energy diverged");
            assert_eq!(st_f, st_l, "churn@{power}: steps diverged");
            assert_eq!(
                g_f.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
                g_l.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            );
            assert_eq!(m_f.rejected_updates, 0);
        }
    }

    /// A strategy that emits a fixed, possibly malformed decision.
    struct FixedDecision {
        clients: Vec<usize>,
    }

    impl Strategy for FixedDecision {
        fn name(&self) -> &'static str {
            "fixed"
        }

        fn needs_forecasts(&self) -> bool {
            false
        }

        fn needs_spare_now(&self) -> bool {
            false
        }

        fn select(&mut self, _ctx: &SelectionContext, _rng: &mut Rng) -> SelectionDecision {
            SelectionDecision {
                clients: self.clients.clone(),
                expected_duration: 10,
                n_required: self.clients.len(),
                max_duration: 10,
                wait: false,
                unconstrained: false,
            }
        }
    }

    /// Satellite 1: a duplicate (or out-of-range) client in a decision
    /// used to panic deep inside `execute_round` when the second
    /// `take()` found an empty slot. Both execution modes must now
    /// reject it at the FSM boundary with a structured error and meter
    /// the rejection.
    #[test]
    fn malformed_decisions_are_rejected_not_a_panic() {
        for bad in [vec![2usize, 5, 2], vec![0usize, 99]] {
            for exec in [ExecMode::Legacy, ExecMode::Fsm] {
                let horizon = 200;
                let (clients, domains, load, load_fc) = build(9, 3, 800.0, horizon);
                let backend = MockBackend::new(9, 8, 0.2, 7);
                let mut s = FixedDecision { clients: bad.clone() };
                let cfg = SimConfig {
                    horizon,
                    n_per_round: 3,
                    d_max: 30,
                    eval_every: 2,
                    seed: 1,
                    step_minutes: 1.0,
                };
                let mut sim = Simulation::new(
                    cfg,
                    clients,
                    domains,
                    load,
                    load_fc,
                    ErrorLevel::Realistic,
                    &backend,
                    &mut s,
                );
                sim.exec = exec;
                let err = sim.run().expect_err("malformed decision must error");
                assert!(
                    err.downcast_ref::<fsm::DecisionError>().is_some(),
                    "{exec:?}: expected a DecisionError, got {err}"
                );
                assert_eq!(sim.metrics.rejected_decisions, 1);
                // no round half-executed: the meter never opened a round
                assert!(sim.metrics.rounds.is_empty());
                assert!(sim.train_states.iter().all(|s| s.is_some()));
            }
        }
    }

    /// Satellite 3: every selected client offline for the whole run —
    /// rounds must close EMPTY on their deadline (no participants, no
    /// energy, flagged timed-out) without panicking and without
    /// advancing participation or utility state.
    #[test]
    fn all_selected_dropping_closes_round_empty() {
        let outages: Vec<Vec<(usize, usize)>> = (0..9).map(|_| vec![(0, 600)]).collect();
        let mut s = Baseline::random();
        let (m, kwh, _, steps) = run_sim_exec(
            &mut s,
            800.0,
            ExecMode::Fsm,
            Some(outages),
            None,
        );
        assert!(!m.rounds.is_empty(), "rounds should still open and close");
        for r in &m.rounds {
            assert!(r.participants.is_empty());
            assert!(r.timed_out, "an empty round must be a timeout close");
            assert_eq!(r.energy_wh, 0.0);
        }
        assert_eq!(kwh, 0.0);
        assert_eq!(steps, 0);
        assert_eq!(m.timeout_rounds(), m.rounds.len());
        assert!(m.participation_counts(9).iter().all(|&c| c == 0));
    }

    /// Tentpole invariant: updates delayed past their round's close are
    /// REJECTED by the epoch fence and metered as waste — never
    /// silently aggregated — and the whole chaotic run is byte-
    /// identical when repeated with the same seed.
    #[test]
    fn stale_updates_after_round_end_are_rejected_and_metered() {
        let chaos = ChaosSpec {
            dropout_per_round: 0.0,
            stale_prob: 1.0,
            mean_delay_min: 40.0, // far beyond the 15-step deadline
            slow_prob: 0.0,
            ..ChaosSpec::default()
        };
        let run = || {
            let mut s = crate::selection::semisync::SemiSync::new(
                FedZero::new(SolverKind::Greedy),
                15,
            );
            run_sim_exec(&mut s, 800.0, ExecMode::Fsm, None, Some(chaos))
        };
        let (m1, kwh1, g1, st1) = run();
        assert!(!m1.rounds.is_empty());
        assert!(
            m1.rejected_updates > 0,
            "long-delayed submissions must be fenced and metered"
        );
        assert!(m1.timeout_rounds() > 0, "delayed rounds must close by deadline");
        // a submission in flight at close means its slot is a straggler
        // whose energy counts as waste
        assert!(m1.total_wasted_kwh() > 0.0);
        // determinism gate: the same seed reproduces the chaotic run
        // byte for byte (fault plans are pure draws)
        let (m2, kwh2, g2, st2) = run();
        assert_eq!(m1, m2, "chaos run not reproducible");
        assert_eq!(kwh1, kwh2);
        assert_eq!(st1, st2);
        assert_eq!(
            g1.iter().map(|x| x.to_bits()).collect::<Vec<_>>(),
            g2.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        );
        assert_eq!(
            m1.to_json().to_string_pretty(),
            m2.to_json().to_string_pretty()
        );
    }

    /// Chaos dropout faults flow through the same depth counters as
    /// churn; a seeded dropout-heavy run is reproducible and differs
    /// from the fault-free run.
    #[test]
    fn chaos_dropouts_are_deterministic_and_perturb_the_run() {
        let chaos = ChaosSpec {
            dropout_per_round: 0.8,
            mean_drop_min: 20.0,
            stale_prob: 0.0,
            slow_prob: 0.0,
            ..ChaosSpec::default()
        };
        let run = |c: Option<ChaosSpec>| {
            let mut s = Baseline::random_over();
            run_sim_exec(&mut s, 100.0, ExecMode::Fsm, None, c)
        };
        let (m_chaos, _, _, _) = run(Some(chaos));
        let (m_chaos2, _, _, _) = run(Some(chaos));
        let (m_clean, _, _, _) = run(None);
        assert_eq!(m_chaos, m_chaos2, "chaos run not reproducible");
        assert_ne!(
            m_chaos, m_clean,
            "a 0.8 dropout rate must perturb the run"
        );
        // faults never corrupt the validation path
        assert_eq!(m_chaos.rejected_decisions, 0);
    }

    /// Slow-client faults scale effective capacity down, stretching
    /// rounds — and never speed anything up.
    #[test]
    fn slow_clients_stretch_rounds() {
        let chaos = ChaosSpec {
            dropout_per_round: 0.0,
            stale_prob: 0.0,
            slow_prob: 1.0,
            slow_factor: 0.5,
            ..ChaosSpec::default()
        };
        let run = |c: Option<ChaosSpec>| {
            let mut s = Baseline::random();
            run_sim_exec(&mut s, 800.0, ExecMode::Fsm, None, c)
        };
        let (m_slow, _, _, _) = run(Some(chaos));
        let (m_clean, _, _, _) = run(None);
        assert!(!m_slow.rounds.is_empty());
        // Random never waits here (constant power, zero load), so round
        // j selects the same cohort in both runs — slow round j can only
        // take at least as long as its clean twin
        for (rs, rc) in m_slow.rounds.iter().zip(&m_clean.rounds) {
            assert_eq!(rs.selected, rc.selected, "selection sequences drifted");
            assert!(
                rs.duration_steps >= rc.duration_steps,
                "halving capacity shortened round {}: {} < {}",
                rs.round,
                rs.duration_steps,
                rc.duration_steps
            );
        }
        assert_ne!(m_slow, m_clean);
    }

    /// The legacy loop has no event vocabulary: combining it with chaos
    /// must be refused up front, not silently ignored.
    #[test]
    fn chaos_requires_fsm_mode() {
        let horizon = 100;
        let (clients, domains, load, load_fc) = build(9, 3, 800.0, horizon);
        let backend = MockBackend::new(9, 8, 0.2, 7);
        let mut s = Baseline::random();
        let cfg = SimConfig {
            horizon,
            n_per_round: 3,
            d_max: 30,
            eval_every: 2,
            seed: 1,
            step_minutes: 1.0,
        };
        let mut sim = Simulation::new(
            cfg,
            clients,
            domains,
            load,
            load_fc,
            ErrorLevel::Realistic,
            &backend,
            &mut s,
        );
        sim.exec = ExecMode::Legacy;
        sim.chaos = Some(ChaosSpec::default());
        let err = sim.run().expect_err("legacy + chaos must be refused");
        assert!(err.to_string().contains("ExecMode::Fsm"), "got: {err}");
        assert!(sim.metrics.rounds.is_empty());
    }

    /// The churn-aware wrapper runs end to end through the engine and
    /// pads its cohort once dropouts are observed.
    #[test]
    fn churn_aware_overselection_reacts_to_dropouts() {
        use crate::selection::adaptive::ChurnAware;
        let chaos = ChaosSpec {
            dropout_per_round: 0.7,
            mean_drop_min: 30.0,
            stale_prob: 0.0,
            slow_prob: 0.0,
            ..ChaosSpec::default()
        };
        let mut ca = ChurnAware::new(Baseline::random(), "Random ca", true);
        let (m, _, _, _) =
            run_sim_exec(&mut ca, 800.0, ExecMode::Fsm, None, Some(chaos));
        assert!(!m.rounds.is_empty());
        assert!(ca.p_hat() > 0.0, "dropouts were observed but p_hat stayed 0");
        assert!(
            m.rounds.iter().any(|r| r.selected.len() > 3),
            "no round was over-selected despite sustained dropouts"
        );
        // quorum stays pinned at n: a padded round that reaches 3
        // submissions closes without waiting for the padding
        for r in &m.rounds {
            assert!(r.selected.len() <= 6, "padding exceeded MAX_FACTOR");
        }
    }

    // ---- durability: journal, snapshots, crash-fault recovery ----

    fn scratch_dir(name: &str) -> std::path::PathBuf {
        let d = std::env::temp_dir()
            .join(format!("fedzero_engine_{}_{}", std::process::id(), name));
        let _ = std::fs::remove_dir_all(&d);
        d
    }

    /// Mixed-fault chaos (dropouts, stale delays, slow clients) with a
    /// configurable coordinator-death probability — the non-crash draws
    /// are identical regardless of `crash_prob` (own stream).
    fn durable_chaos(crash_prob: f64) -> ChaosSpec {
        ChaosSpec {
            dropout_per_round: 0.4,
            mean_drop_min: 20.0,
            stale_prob: 0.2,
            slow_prob: 0.2,
            slow_factor: 0.5,
            crash_prob,
            ..ChaosSpec::default()
        }
    }

    /// Run (or resume) the 9-client fixture under ChurnAware wrapping —
    /// the one strategy with cross-round internal state, so the
    /// snapshot's `strategy_state` round-trip is genuinely exercised.
    /// `dir: Some` arms the durable coordinator with `snapshot_every=3`.
    fn run_durable(
        seed: u64,
        crash_prob: f64,
        dir: Option<&std::path::Path>,
        resume: bool,
    ) -> Result<(MetricsLog, f64, Vec<f32>, u64)> {
        use crate::selection::adaptive::ChurnAware;
        let horizon = 600;
        let (clients, domains, load, load_fc) = build(9, 3, 200.0, horizon);
        let mut backend = MockBackend::new(9, 8, 0.2, 7);
        backend.par_min_jobs = usize::MAX;
        let cfg = SimConfig {
            horizon,
            n_per_round: 3,
            d_max: 30,
            eval_every: 2,
            seed,
            step_minutes: 1.0,
        };
        let mut ca = ChurnAware::new(Baseline::random(), "Random ca", true);
        let mut sim = Simulation::new(
            cfg,
            clients,
            domains,
            load,
            load_fc,
            ErrorLevel::Realistic,
            &backend,
            &mut ca,
        );
        sim.par_domains_min = usize::MAX;
        sim.par_slots_min = usize::MAX;
        sim.chaos = Some(durable_chaos(crash_prob));
        if let Some(d) = dir {
            // the cadence is part of the journal's byte stream, so the
            // resume leg pins the same value as the original run
            sim.durable =
                Some(DurableConfig { dir: d.to_path_buf(), snapshot_every: 3 });
        }
        if resume {
            sim.resume_from(dir.expect("resume needs a checkpoint dir"))?;
        } else {
            sim.run()?;
        }
        let kwh = sim.meter.total_kwh();
        let steps = sim.steps_executed();
        let global = std::mem::take(&mut sim.final_global);
        Ok((sim.metrics, kwh, global, steps))
    }

    fn bits(v: &[f32]) -> Vec<u32> {
        v.iter().map(|x| x.to_bits()).collect()
    }

    /// THE recovery gate of the PR: kill the coordinator at a seeded
    /// chaos step, resume from the surviving journal + snapshots, and
    /// demand the resumed run be indistinguishable from one that never
    /// crashed — MetricsLog (every f64 included), total energy, step
    /// counts, final global model bits, and the journal bytes
    /// themselves. Also pins that journaling is a pure observer: the
    /// durable run equals the non-durable run bit for bit.
    #[test]
    fn crash_then_resume_is_bit_identical() {
        for seed in [1u64, 2, 5] {
            let dir_a = scratch_dir(&format!("ref_{seed}"));
            let dir_b = scratch_dir(&format!("crash_{seed}"));

            // reference: durable, crash disarmed, runs to completion
            let (m_ref, kwh_ref, g_ref, st_ref) =
                run_durable(seed, 0.0, Some(&dir_a), false).unwrap();
            assert!(!m_ref.rounds.is_empty(), "seed {seed}: fixture did no rounds");

            // journaling must not perturb the simulation itself
            let (m_plain, kwh_plain, g_plain, st_plain) =
                run_durable(seed, 0.0, None, false).unwrap();
            assert_eq!(m_plain, m_ref, "seed {seed}: journaling perturbed metrics");
            assert_eq!(kwh_plain, kwh_ref);
            assert_eq!(st_plain, st_ref);
            assert_eq!(bits(&g_plain), bits(&g_ref));

            // the completed journal replays cleanly and covers every round
            let (_, records) = Journal::open(&dir_a.join("journal.wal")).unwrap();
            assert_eq!(
                journal::verify_replay(&records).unwrap(),
                m_ref.rounds.len(),
                "seed {seed}: journal round count diverged from metrics"
            );

            // crash_prob = 1.0 guarantees a coordinator death mid-run
            let err = run_durable(seed, 1.0, Some(&dir_b), false)
                .expect_err("crash_prob=1 must kill the run");
            let fault = err
                .downcast_ref::<CrashFault>()
                .unwrap_or_else(|| panic!("seed {seed}: not a CrashFault: {err}"));
            assert!(
                fault.at >= 1 && fault.at < 600,
                "seed {seed}: crash step {} out of range",
                fault.at
            );

            // resume from the crash dir — same chaos spec (crash still
            // armed in the spec; resume disarms the drawn fault)
            let (m_res, kwh_res, g_res, st_res) =
                run_durable(seed, 1.0, Some(&dir_b), true).unwrap();
            assert_eq!(m_res, m_ref, "seed {seed}: resumed metrics diverged");
            assert_eq!(kwh_res, kwh_ref, "seed {seed}: resumed energy diverged");
            assert_eq!(st_res, st_ref, "seed {seed}: resumed steps diverged");
            assert_eq!(
                bits(&g_res),
                bits(&g_ref),
                "seed {seed}: resumed global model diverged"
            );

            // the resumed journal's bytes equal the never-crashed one's:
            // truncate-to-mark plus deterministic re-execution re-appends
            // exactly the records the crash lost
            assert_eq!(
                std::fs::read(dir_a.join("journal.wal")).unwrap(),
                std::fs::read(dir_b.join("journal.wal")).unwrap(),
                "seed {seed}: journal bytes diverged after resume"
            );

            let _ = std::fs::remove_dir_all(&dir_a);
            let _ = std::fs::remove_dir_all(&dir_b);
        }
    }

    #[test]
    fn durable_requires_fsm_mode() {
        let dir = scratch_dir("legacy");
        let horizon = 600;
        let (clients, domains, load, load_fc) = build(9, 3, 200.0, horizon);
        let backend = MockBackend::new(9, 8, 0.2, 7);
        let mut s = Baseline::random();
        let cfg = SimConfig {
            horizon,
            n_per_round: 3,
            d_max: 30,
            eval_every: 2,
            seed: 1,
            step_minutes: 1.0,
        };
        let mut sim = Simulation::new(
            cfg,
            clients,
            domains,
            load,
            load_fc,
            ErrorLevel::Realistic,
            &backend,
            &mut s,
        );
        sim.exec = ExecMode::Legacy;
        sim.durable = Some(DurableConfig::new(&dir));
        let err = sim.run().expect_err("legacy + durable must be refused");
        assert!(err.to_string().contains("ExecMode::Fsm"), "got: {err}");
        let err = sim
            .resume_from(&dir)
            .expect_err("legacy + resume must be refused");
        assert!(err.to_string().contains("ExecMode::Fsm"), "got: {err}");
        assert!(sim.metrics.rounds.is_empty());
        let _ = std::fs::remove_dir_all(&dir);
    }

    #[test]
    fn resume_rejects_mismatched_config_and_empty_dirs() {
        let dir = scratch_dir("mismatch");
        run_durable(1, 0.0, Some(&dir), false).unwrap();
        // a different seed is a different run — the snapshot's config
        // echo refuses to graft its state onto this simulation
        let err = run_durable(2, 0.0, Some(&dir), true)
            .expect_err("mismatched seed must be refused");
        assert!(err.to_string().contains("mismatch"), "got: {err}");
        // no checkpoints at all -> a clear error, not a silent fresh run
        let empty = scratch_dir("empty");
        std::fs::create_dir_all(&empty).unwrap();
        let err = run_durable(1, 0.0, Some(&empty), true)
            .expect_err("empty checkpoint dir must be refused");
        assert!(err.to_string().contains("no valid snapshot"), "got: {err}");
        let _ = std::fs::remove_dir_all(&dir);
        let _ = std::fs::remove_dir_all(&empty);
    }
}
