//! The simulation engine.

use anyhow::Result;

use crate::client::ClientInfo;
use crate::energy::{attribute_power, EnergyMeter, PowerDomain, PowerRequest};
use crate::fl::{fedavg_weights, TrainBackend};
use crate::metrics::{EvalRecord, MetricsLog, RoundRecord};
use crate::selection::oort::UtilityTracker;
use crate::selection::{ClientRoundState, SelectionContext, SelectionDecision, Strategy};
use crate::trace::forecast::{ErrorLevel, SeriesForecaster};
use crate::util::rng::Rng;

#[derive(Clone, Debug)]
pub struct SimConfig {
    pub step_minutes: f64,
    /// total simulated steps (paper: 7 days = 10080 one-minute steps)
    pub horizon: usize,
    /// clients selected per round (n)
    pub n_per_round: usize,
    /// max round duration in steps (d_max)
    pub d_max: usize,
    /// evaluate the global model every this many rounds
    pub eval_every: usize,
    pub seed: u64,
}

impl Default for SimConfig {
    fn default() -> Self {
        SimConfig {
            step_minutes: 1.0,
            horizon: 7 * 24 * 60,
            n_per_round: 10,
            d_max: 60,
            eval_every: 5,
            seed: 0,
        }
    }
}

/// Outcome of one executed round.
#[derive(Clone, Debug)]
pub struct RoundOutcome {
    pub duration: usize,
    /// clients that reached m_min (their updates were aggregated)
    pub participants: Vec<usize>,
    /// clients whose work was discarded (selected, did not reach m_min)
    pub stragglers: Vec<usize>,
    pub total_batches: f64,
    pub energy_wh: f64,
}

/// Everything needed to simulate one experiment configuration.
pub struct Simulation<'a, B: TrainBackend> {
    pub cfg: SimConfig,
    pub clients: Vec<ClientInfo>,
    pub domains: Vec<PowerDomain>,
    /// actual utilisation per client per step ([0,1]); spare capacity is
    /// m_c · (1 − util)
    pub load_actual: Vec<Vec<f64>>,
    /// spare-capacity forecasters per client (over the spare series, in
    /// batches/step); `ErrorLevel::Unavailable` means "assume full m_c"
    pub load_fc: Vec<SeriesForecaster>,
    pub load_fc_level: ErrorLevel,
    pub backend: &'a mut B,
    pub strategy: &'a mut dyn Strategy,
    // --- state ---
    pub states: Vec<ClientRoundState>,
    pub utility: UtilityTracker,
    pub meter: EnergyMeter,
    pub metrics: MetricsLog,
    pub rng: Rng,
    /// wall-clock spent inside strategy.select (overhead accounting)
    pub select_time: std::time::Duration,
}

impl<'a, B: TrainBackend> Simulation<'a, B> {
    #[allow(clippy::too_many_arguments)]
    pub fn new(
        cfg: SimConfig,
        clients: Vec<ClientInfo>,
        domains: Vec<PowerDomain>,
        load_actual: Vec<Vec<f64>>,
        load_fc: Vec<SeriesForecaster>,
        load_fc_level: ErrorLevel,
        backend: &'a mut B,
        strategy: &'a mut dyn Strategy,
    ) -> Self {
        let n_clients = clients.len();
        let n_domains = domains.len();
        let seed = cfg.seed;
        let step_minutes = cfg.step_minutes;
        Simulation {
            cfg,
            clients,
            domains,
            load_actual,
            load_fc,
            load_fc_level,
            backend,
            strategy,
            states: vec![ClientRoundState::default(); n_clients],
            utility: UtilityTracker::new(n_clients),
            meter: EnergyMeter::new(n_clients, n_domains),
            metrics: MetricsLog::new(step_minutes),
            rng: Rng::new(seed ^ 0x51D),
            select_time: std::time::Duration::ZERO,
        }
    }

    /// actual spare capacity of client `i` at step `t` (batches/step)
    fn spare_actual(&self, i: usize, t: usize) -> f64 {
        let util = self
            .load_actual
            .get(i)
            .and_then(|v| v.get(t))
            .copied()
            .unwrap_or(1.0);
        self.clients[i].capacity() * (1.0 - util)
    }

    /// spare-capacity forecast window for client `i` issued at `t0`,
    /// written into a reused buffer
    fn spare_forecast_window_into(&self, i: usize, t0: usize, h: usize, out: &mut Vec<f64>) {
        out.clear();
        match self.load_fc_level {
            ErrorLevel::Unavailable => {
                out.resize(h, self.clients[i].capacity());
            }
            _ => {
                let cap = self.clients[i].capacity();
                out.extend(
                    (t0..t0 + h).map(|t| self.load_fc[i].forecast(t0, t).clamp(0.0, cap)),
                );
            }
        }
    }

    /// Run the full simulation: returns the metrics log (also stored).
    pub fn run(&mut self) -> Result<()> {
        let mut global = self.backend.init_params(self.cfg.seed as i32)?;
        let mut t = 0usize;
        let mut round = 0usize;
        // §Perf: forecast/state buffers are hoisted out of the step loop
        // and refilled in place — selection attempts during idle (dark)
        // periods no longer allocate 2·C + D vectors per step.
        let mut samples: Vec<usize> = Vec::with_capacity(self.clients.len());
        let mut energy_fc: Vec<Vec<f64>> = vec![Vec::new(); self.domains.len()];
        let mut spare_fc: Vec<Vec<f64>> = vec![Vec::new(); self.clients.len()];
        let mut spare_now: Vec<f64> = Vec::with_capacity(self.clients.len());
        while t < self.cfg.horizon {
            // refresh σ, assemble context, ask the strategy
            samples.clear();
            samples.extend(self.clients.iter().map(|c| c.num_samples()));
            self.utility.refresh(&mut self.states, &samples);

            // §Perf: forecast windows are only materialised for strategies
            // that read them (FedZero, *-fc); Random/Oort/UpperBound skip
            // ~C·d_max hash-noise draws per selection attempt.
            let wants_fc = self.strategy.needs_forecasts();
            if wants_fc {
                for (p, buf) in energy_fc.iter_mut().enumerate() {
                    self.domains[p].forecast_window_wh_into(t, self.cfg.d_max, buf);
                }
                for (i, buf) in spare_fc.iter_mut().enumerate() {
                    self.spare_forecast_window_into(i, t, self.cfg.d_max, buf);
                }
            }
            spare_now.clear();
            spare_now.extend((0..self.clients.len()).map(|i| self.spare_actual(i, t)));
            let decision = {
                let ctx = SelectionContext {
                    now: t,
                    n: self.cfg.n_per_round,
                    d_max: self.cfg.d_max,
                    clients: &self.clients,
                    states: &self.states,
                    domains: &self.domains,
                    energy_fc: &energy_fc,
                    spare_fc: &spare_fc,
                    spare_now: &spare_now,
                };
                let t0 = std::time::Instant::now();
                let d = self.strategy.select(&ctx, &mut self.rng);
                self.select_time += t0.elapsed();
                d
            };
            if decision.wait {
                t += 1;
                continue;
            }

            let outcome = self.execute_round(&decision, t, &global)?;

            // aggregate participant updates (weights = sample counts)
            let participants = outcome.0.participants.clone();
            if !participants.is_empty() {
                let weights = fedavg_weights(
                    &participants
                        .iter()
                        .map(|&c| self.clients[c].num_samples())
                        .collect::<Vec<_>>(),
                );
                global = self.backend.aggregate(&outcome.1, &weights)?;
            }

            // bookkeeping: utility, participation, blocklist
            for (&c, &loss) in participants.iter().zip(&outcome.2) {
                self.states[c].participation += 1;
                self.utility.update(c, loss, self.clients[c].num_samples());
            }
            self.strategy.on_round_end(
                &participants,
                &mut self.states,
                &mut self.rng,
            );

            let out = &outcome.0;
            let mean_loss = if outcome.2.is_empty() {
                0.0
            } else {
                outcome.2.iter().sum::<f64>() / outcome.2.len() as f64
            };
            self.metrics.rounds.push(RoundRecord {
                round,
                start_step: t,
                duration_steps: out.duration,
                selected: decision.clients.clone(),
                participants: participants.clone(),
                batches: out.total_batches,
                energy_wh: out.energy_wh,
                mean_loss,
            });

            t += out.duration.max(1);
            round += 1;

            if round % self.cfg.eval_every == 0 || t >= self.cfg.horizon {
                let (acc, loss) = self.backend.evaluate(&global)?;
                self.metrics.evals.push(EvalRecord {
                    round,
                    step: t,
                    accuracy: acc,
                    loss,
                    cumulative_kwh: self.meter.total_kwh(),
                });
            }
        }
        Ok(())
    }

    /// Execute one round starting at `t0`. Returns (outcome, participant
    /// updated params aligned with outcome.participants, participant mean
    /// losses).
    #[allow(clippy::type_complexity)]
    fn execute_round(
        &mut self,
        decision: &SelectionDecision,
        t0: usize,
        global: &[f32],
    ) -> Result<(RoundOutcome, Vec<Vec<f32>>, Vec<f64>)> {
        self.meter.begin_round();
        let sel = &decision.clients;
        let k = sel.len();
        let mut local: Vec<Vec<f32>> = vec![global.to_vec(); k];
        let mut progress = vec![0.0f64; k]; // fractional batch credit
        let mut executed = vec![0usize; k]; // whole batches run
        let mut loss_acc = vec![0.0f64; k];
        let mut loss_batches = vec![0usize; k];
        let mut duration = 0usize;

        // group selected clients by domain once
        let mut by_domain: std::collections::BTreeMap<usize, Vec<usize>> =
            std::collections::BTreeMap::new();
        for (slot, &c) in sel.iter().enumerate() {
            by_domain
                .entry(self.clients[c].domain)
                .or_default()
                .push(slot);
        }

        let round_cap = decision.max_duration.max(1).min(self.cfg.d_max);
        for step in 0..round_cap {
            let tt = t0 + step;
            if tt >= self.cfg.horizon {
                break;
            }
            duration = step + 1;

            for (&dom, slots) in &by_domain {
                // demands of still-active clients in this domain
                let mut active: Vec<usize> = slots
                    .iter()
                    .copied()
                    .filter(|&s| {
                        progress[s] < self.clients[sel[s]].m_max - 1e-9
                    })
                    .collect();
                if active.is_empty() {
                    continue;
                }
                let batch_steps: Vec<f64> = if decision.unconstrained {
                    // Upper bound: full capacity, grid energy
                    active
                        .iter()
                        .map(|&s| {
                            let c = &self.clients[sel[s]];
                            c.capacity().min(c.m_max - progress[s])
                        })
                        .collect()
                } else {
                    let reqs: Vec<PowerRequest> = active
                        .iter()
                        .map(|&s| {
                            let c = &self.clients[sel[s]];
                            let delta = c.delta();
                            let spare = self.spare_actual(sel[s], tt);
                            PowerRequest {
                                need_min_wh: delta
                                    * (c.m_min - progress[s]).max(0.0),
                                need_max_wh: delta
                                    * (c.m_max - progress[s]).max(0.0),
                                usable_wh: delta
                                    * spare.min(c.m_max - progress[s]).max(0.0),
                            }
                        })
                        .collect();
                    let available = self.domains[dom].energy_wh(tt);
                    let alloc = if available.is_infinite() {
                        // unlimited domain: everyone gets their cap
                        reqs.iter()
                            .map(|r| r.usable_wh.min(r.need_max_wh))
                            .collect()
                    } else {
                        attribute_power(available, &reqs)
                    };
                    active
                        .iter()
                        .zip(&alloc)
                        .map(|(&s, &wh)| wh / self.clients[sel[s]].delta())
                        .collect()
                };

                for (idx, &s) in active.iter().enumerate() {
                    let b = batch_steps[idx];
                    if b <= 0.0 {
                        continue;
                    }
                    progress[s] += b;
                    let wh = b * self.clients[sel[s]].delta();
                    self.meter.record(sel[s], dom, wh);
                    // run the whole batches that became available
                    let want = progress[s].floor() as usize;
                    if want > executed[s] {
                        let n_new = want - executed[s];
                        let stats = self.backend.train_batches(
                            sel[s],
                            &mut local[s],
                            global,
                            n_new,
                        )?;
                        loss_acc[s] += stats.mean_loss * n_new as f64;
                        loss_batches[s] += n_new;
                        executed[s] = want;
                    }
                }
                // placate borrowck lint: active consumed here
                active.clear();
            }

            // end condition: n_required clients reached their minimum
            let done = (0..k)
                .filter(|&s| progress[s] >= self.clients[sel[s]].m_min - 1e-9)
                .count();
            if done >= decision.n_required {
                break;
            }
        }

        let mut participants = Vec::new();
        let mut stragglers = Vec::new();
        let mut updates = Vec::new();
        let mut losses = Vec::new();
        for s in 0..k {
            if progress[s] >= self.clients[sel[s]].m_min - 1e-9
                && executed[s] > 0
            {
                participants.push(sel[s]);
                updates.push(std::mem::take(&mut local[s]));
                losses.push(if loss_batches[s] > 0 {
                    loss_acc[s] / loss_batches[s] as f64
                } else {
                    0.0
                });
            } else {
                stragglers.push(sel[s]);
            }
        }
        let total_batches: f64 = progress.iter().sum();
        let energy_wh = self.meter.round_wh(self.meter.rounds() - 1);
        Ok((
            RoundOutcome {
                duration,
                participants,
                stragglers,
                total_batches,
                energy_wh,
            },
            updates,
            losses,
        ))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::client::{ClientProfile, DeviceType, ModelKind};
    use crate::fl::MockBackend;
    use crate::selection::baselines::{Baseline, UpperBound};
    use crate::selection::fedzero::{FedZero, SolverKind};

    fn build(
        n_clients: usize,
        n_domains: usize,
        power_w: f64,
        horizon: usize,
    ) -> (Vec<ClientInfo>, Vec<PowerDomain>, Vec<Vec<f64>>, Vec<SeriesForecaster>)
    {
        let clients: Vec<ClientInfo> = (0..n_clients)
            .map(|i| {
                let p = ClientProfile::new(
                    DeviceType::ALL[i % 3],
                    ModelKind::Vision,
                    10,
                    1.0,
                );
                ClientInfo::new(i, i % n_domains, p, (0..60).collect(), 10)
            })
            .collect();
        let domains: Vec<PowerDomain> = (0..n_domains)
            .map(|i| {
                let series = vec![power_w; horizon];
                PowerDomain::new(
                    i,
                    "d",
                    800.0,
                    series.clone(),
                    SeriesForecaster::perfect(series),
                    1.0,
                )
            })
            .collect();
        let load: Vec<Vec<f64>> =
            (0..n_clients).map(|_| vec![0.0; horizon]).collect();
        let load_fc: Vec<SeriesForecaster> = clients
            .iter()
            .map(|c| {
                SeriesForecaster::perfect(vec![c.capacity(); horizon])
            })
            .collect();
        (clients, domains, load, load_fc)
    }

    fn run_sim(
        strategy: &mut dyn Strategy,
        power_w: f64,
    ) -> (MetricsLog, f64) {
        let horizon = 600;
        let (clients, domains, load, load_fc) = build(9, 3, power_w, horizon);
        let mut backend = MockBackend::new(9, 8, 0.2, 7);
        let cfg = SimConfig {
            horizon,
            n_per_round: 3,
            d_max: 30,
            eval_every: 2,
            seed: 1,
            step_minutes: 1.0,
        };
        let mut sim = Simulation::new(
            cfg,
            clients,
            domains,
            load,
            load_fc,
            ErrorLevel::Realistic,
            &mut backend,
            strategy,
        );
        sim.run().unwrap();
        let kwh = sim.meter.total_kwh();
        (sim.metrics, kwh)
    }

    #[test]
    fn fedzero_trains_and_converges_on_mock() {
        let mut fz = FedZero::new(SolverKind::Greedy);
        let (m, kwh) = run_sim(&mut fz, 800.0);
        assert!(m.rounds.len() > 5, "only {} rounds", m.rounds.len());
        assert!(m.best_accuracy() > 0.5, "acc {}", m.best_accuracy());
        assert!(kwh > 0.0);
        // energy accounting consistent between meter and metrics
        assert!((kwh - m.total_energy_kwh()).abs() < 1e-9);
    }

    #[test]
    fn all_baselines_run() {
        for mut s in [
            Baseline::random(),
            Baseline::random_over(),
            Baseline::random_fc(),
            Baseline::oort(),
            Baseline::oort_over(),
            Baseline::oort_fc(),
        ] {
            let (m, _) = run_sim(&mut s, 800.0);
            assert!(!m.rounds.is_empty(), "{} did no rounds", s.name());
        }
        let mut ub = UpperBound;
        let (m, _) = run_sim(&mut ub, 0.0); // no excess energy needed
        assert!(m.best_accuracy() > 0.5);
    }

    #[test]
    fn no_power_means_no_rounds_except_upper_bound() {
        let mut fz = FedZero::new(SolverKind::Greedy);
        let (m, kwh) = run_sim(&mut fz, 0.0);
        assert!(m.rounds.is_empty());
        assert_eq!(kwh, 0.0);
    }

    #[test]
    fn energy_budget_is_respected_per_domain_step() {
        // run with modest power and verify no round used more energy than
        // domains could provide: total kWh <= power * time
        let mut fz = FedZero::new(SolverKind::Greedy);
        let (m, kwh) = run_sim(&mut fz, 100.0);
        let horizon_h = 600.0 / 60.0;
        let max_possible_kwh = 3.0 * 100.0 * horizon_h / 1000.0;
        assert!(kwh <= max_possible_kwh + 1e-9, "{kwh} > {max_possible_kwh}");
        assert!(!m.rounds.is_empty());
    }

    #[test]
    fn over_selection_discards_stragglers() {
        // scarce energy -> with 1.3n over-selection some clients won't
        // finish; participants <= selected
        let mut s = Baseline::random_over();
        let (m, _) = run_sim(&mut s, 60.0);
        let mut saw_discard = false;
        for r in &m.rounds {
            assert!(r.participants.len() <= r.selected.len());
            if r.participants.len() < r.selected.len() {
                saw_discard = true;
            }
        }
        assert!(saw_discard, "expected at least one straggler");
    }

    #[test]
    fn fedzero_rounds_do_not_exceed_dmax() {
        let mut fz = FedZero::new(SolverKind::Greedy);
        let (m, _) = run_sim(&mut fz, 300.0);
        for r in &m.rounds {
            assert!(r.duration_steps <= 30);
        }
    }

    #[test]
    fn participation_is_tracked() {
        let mut fz = FedZero::new(SolverKind::Greedy);
        let (m, _) = run_sim(&mut fz, 800.0);
        let counts = m.participation_counts(9);
        assert_eq!(
            counts.iter().sum::<usize>(),
            m.rounds.iter().map(|r| r.participants.len()).sum::<usize>()
        );
    }
}
