//! Discrete-event FL simulation over energy/load time series — the
//! reproduction of the paper's Flower extension + Vessim testbed (§5).
//!
//! Time advances in fixed steps (1 minute in the paper). Between rounds
//! the engine skips idle time; inside a round it executes the per-step
//! local control loop of §4.5: the domain controller attributes the
//! actually-available excess energy to participating clients (two-step
//! water-filling), clients compute as many whole batches as their energy
//! share and actual spare capacity allow, and the server ends the round
//! when `n_required` clients reached m_min or d_max elapsed. Stragglers'
//! work is discarded (but their energy was still spent — the over-
//! selection waste the paper measures).

//!
//! Round execution is event-driven by default ([`engine::ExecMode`]):
//! the coordinator state machine ([`crate::coordinator::fsm`]) fences
//! stale updates by epoch token and closes rounds on `Timeout` events;
//! [`chaos`] injects seeded dropout / stale-update / slow-client /
//! coordinator-crash faults through that same event vocabulary.
//!
//! Setting [`engine::DurableConfig`] on a simulation makes the
//! coordinator crash-tolerant: a write-ahead journal plus periodic
//! snapshot checkpoints, and [`Simulation::resume_from`] continues a
//! killed run bit-identically to one that never crashed (engine
//! §Durability docs).

pub mod chaos;
pub mod engine;

pub use chaos::{ChaosSpec, CrashFault};
pub use engine::{DurableConfig, ExecMode, RoundOutcome, SimConfig, Simulation};
