//! Chaos engine: seeded fault injection for round execution.
//!
//! Where `scenario::churn` models *availability* (clients vanish on
//! their own schedule, horizon-wide, independent of rounds), chaos
//! models *round-scoped faults* — the failure modes the FedZero paper
//! argues a coordinator must tolerate but which a batch simulator never
//! exercises: a selected client dying mid-round, an update arriving
//! after the round closed (stale epoch token), a device silently
//! running at a fraction of its profiled speed. Each fault becomes an
//! event (`Dropout`/`Rejoin`, a delayed `UpdateSubmitted`) or a
//! capacity scale fed to the round state machine
//! ([`crate::coordinator::fsm`]); nothing here touches the engine's
//! numeric state directly.
//!
//! # Determinism rules
//!
//! A client's fault plan for a round is a **pure function** of
//! `(experiment seed, client id, round start step)` — the draw happens
//! in [`ChaosSpec::round_plan`] on a freshly seeded [`Rng`] with a
//! dedicated stream tag, in a fixed draw order (drop? → offset →
//! duration → delay? → slow?). Consequences:
//!
//! * two runs with the same seed produce byte-identical fault
//!   schedules — the two-run gate in `ci.sh` / `benches/chaos.rs`;
//! * plans are independent of evaluation order, so campaign reports
//!   are byte-identical at any worker count;
//! * adding chaos to a spec cannot perturb churn, partitioning, or any
//!   other seeded stream (independent stream tags, same idiom as
//!   `CHURN_STREAM`).
//!
//! Chaos requires the FSM execution path (`ExecMode::Fsm`); the legacy
//! loop has no event vocabulary to express these faults and the engine
//! refuses the combination rather than silently ignoring it.
//!
//! # JSON schema (an `EnvSpec`'s optional `"chaos"` key)
//!
//! ```json
//! {
//!   "dropout_per_round": 0.1,   // P(mid-round fault) per selected client per round
//!   "mean_drop_min":     15.0,  // mean fault duration, minutes (exponential)
//!   "stale_prob":        0.05,  // P(update submission is delayed)
//!   "mean_delay_min":    10.0,  // mean submission delay, minutes (exponential)
//!   "slow_prob":         0.1,   // P(client runs slow this round)
//!   "slow_factor":       0.5,   // capacity multiplier when slow, in (0, 1]
//!   "crash_prob":        0.0    // P(the coordinator process dies mid-run)
//! }
//! ```
//!
//! The crash fault is a different beast from the per-client faults: it
//! kills the *coordinator* at a seeded timestep (one Bernoulli draw per
//! run on its own stream, then a uniform timestep), aborting `run()`
//! with a downcastable [`CrashFault`]. It exists to exercise the
//! durable-coordinator path — journal + snapshots + `resume_from` —
//! whose gate asserts crash-then-resume is bit-identical to an
//! uninterrupted run.

use anyhow::{bail, Result};

use crate::util::json::Json;
use crate::util::rng::Rng;

/// Stream tag separating chaos draws from churn and every other
/// consumer of the experiment seed.
const CHAOS_STREAM: u64 = 0x43_48_41_4F_53; // "CHAOS"

/// Stream tag for the coordinator-crash draw. Separate from
/// `CHAOS_STREAM` so arming `crash_prob` cannot perturb any per-client
/// fault plan: a `crash_prob = 0` run and a crashing run are
/// bit-identical up to the crash step.
const CRASH_STREAM: u64 = 0x43_52_41_53_48; // "CRASH"

/// Fault-injection axis of an [`crate::scenario::EnvSpec`]. Applied at
/// simulation time (it does not affect the environment build, so
/// campaign cells differing only in chaos share a memoised build).
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct ChaosSpec {
    /// probability a selected client suffers a mid-round dropout fault
    pub dropout_per_round: f64,
    /// mean fault duration in minutes (exponential, floored to 1 step)
    pub mean_drop_min: f64,
    /// probability a client's update submission is delayed past the
    /// step it finishes in (stale if the round closes first)
    pub stale_prob: f64,
    /// mean submission delay in minutes (exponential, floored to 1 step)
    pub mean_delay_min: f64,
    /// probability a client runs slow for the whole round
    pub slow_prob: f64,
    /// effective-capacity multiplier for a slow client, in (0, 1]
    pub slow_factor: f64,
    /// probability the coordinator process crashes at a seeded timestep
    /// during the run (0 = never; requires the durable path to recover)
    pub crash_prob: f64,
}

impl Default for ChaosSpec {
    fn default() -> Self {
        ChaosSpec {
            dropout_per_round: 0.1,
            mean_drop_min: 15.0,
            stale_prob: 0.05,
            mean_delay_min: 10.0,
            slow_prob: 0.1,
            slow_factor: 0.5,
            crash_prob: 0.0,
        }
    }
}

/// Error type the engine aborts with when the seeded crash fault fires.
/// Callers downcast (`err.downcast_ref::<CrashFault>()`) to tell a
/// simulated coordinator death apart from a real failure, then recover
/// via `Simulation::resume_from`.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub struct CrashFault {
    /// the timestep at which the coordinator died
    pub at: usize,
}

impl std::fmt::Display for CrashFault {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "chaos crash fault: coordinator died at step {}", self.at)
    }
}

impl std::error::Error for CrashFault {}

/// One client's fault plan for one round.
#[derive(Clone, Copy, Debug, PartialEq)]
pub struct SlotChaos {
    /// offline window relative to round start: `(offset, len)` steps.
    /// `offset == 0` means the fault is already open at round start.
    pub drop_window: Option<(usize, usize)>,
    /// steps between finishing `m_min` and the update actually
    /// arriving (0 = same step, the no-fault behavior)
    pub submit_delay: usize,
    /// effective-capacity multiplier for this round (1.0 = nominal)
    pub slow: f64,
}

impl SlotChaos {
    pub const NONE: SlotChaos =
        SlotChaos { drop_window: None, submit_delay: 0, slow: 1.0 };
}

impl ChaosSpec {
    pub fn from_json(j: &Json) -> Result<ChaosSpec> {
        let d = ChaosSpec::default();
        let spec = ChaosSpec {
            dropout_per_round: j
                .get("dropout_per_round")
                .and_then(|v| v.as_f64())
                .unwrap_or(d.dropout_per_round),
            mean_drop_min: j
                .get("mean_drop_min")
                .and_then(|v| v.as_f64())
                .unwrap_or(d.mean_drop_min),
            stale_prob: j.get("stale_prob").and_then(|v| v.as_f64()).unwrap_or(d.stale_prob),
            mean_delay_min: j
                .get("mean_delay_min")
                .and_then(|v| v.as_f64())
                .unwrap_or(d.mean_delay_min),
            slow_prob: j.get("slow_prob").and_then(|v| v.as_f64()).unwrap_or(d.slow_prob),
            slow_factor: j.get("slow_factor").and_then(|v| v.as_f64()).unwrap_or(d.slow_factor),
            crash_prob: j.get("crash_prob").and_then(|v| v.as_f64()).unwrap_or(d.crash_prob),
        };
        spec.validate()?;
        Ok(spec)
    }

    pub fn validate(&self) -> Result<()> {
        for (name, p) in [
            ("dropout_per_round", self.dropout_per_round),
            ("stale_prob", self.stale_prob),
            ("slow_prob", self.slow_prob),
            ("crash_prob", self.crash_prob),
        ] {
            if !(0.0..=1.0).contains(&p) {
                bail!("chaos {name} must be a probability in [0, 1], got {p}");
            }
        }
        if self.mean_drop_min <= 0.0 || self.mean_delay_min <= 0.0 {
            bail!(
                "chaos mean_drop_min / mean_delay_min must be > 0, got {} / {}",
                self.mean_drop_min,
                self.mean_delay_min
            );
        }
        if !(self.slow_factor > 0.0 && self.slow_factor <= 1.0) {
            bail!("chaos slow_factor must be in (0, 1], got {}", self.slow_factor);
        }
        Ok(())
    }

    /// Draw client `client`'s fault plan for the round starting at step
    /// `t0` with duration cap `round_cap`. Pure in `(self, seed,
    /// client, t0, round_cap, step_minutes)` — see the module docs for
    /// why that purity is the determinism guarantee.
    pub fn round_plan(
        &self,
        seed: u64,
        client: usize,
        t0: usize,
        round_cap: usize,
        step_minutes: f64,
    ) -> SlotChaos {
        let mut rng = Rng::new(
            seed ^ CHAOS_STREAM
                ^ (client as u64).wrapping_mul(0x9E3779B97F4A7C15)
                ^ (t0 as u64).wrapping_mul(0xA24BAED4963EE407),
        );
        // fixed draw order: drop? → offset → duration → delay? → slow?
        let drop_window = if rng.bool(self.dropout_per_round) {
            let off = rng.below(round_cap.max(1));
            let mean_steps = (self.mean_drop_min / step_minutes).max(1.0);
            let len = (rng.exponential(1.0 / mean_steps).ceil() as usize).max(1);
            Some((off, len))
        } else {
            None
        };
        let submit_delay = if rng.bool(self.stale_prob) {
            let mean_steps = (self.mean_delay_min / step_minutes).max(1.0);
            (rng.exponential(1.0 / mean_steps).ceil() as usize).max(1)
        } else {
            0
        };
        let slow = if rng.bool(self.slow_prob) { self.slow_factor } else { 1.0 };
        SlotChaos { drop_window, submit_delay, slow }
    }

    /// Draw the coordinator-crash timestep for a run over `horizon`
    /// steps: `None` when the Bernoulli draw spares the run (or
    /// `crash_prob` is 0), else `Some(t)` with `t` in `[1, horizon)` —
    /// never step 0, so every crashing run has at least one live step
    /// to journal. Pure in `(self.crash_prob, seed, horizon)` and on a
    /// dedicated stream, so arming it cannot move any other draw.
    pub fn draw_crash(&self, seed: u64, horizon: usize) -> Option<usize> {
        if self.crash_prob <= 0.0 || horizon < 2 {
            return None;
        }
        let mut rng = Rng::new(seed ^ CRASH_STREAM);
        if rng.f64() >= self.crash_prob {
            return None;
        }
        Some(1 + rng.below(horizon - 1))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn round_plan_is_a_pure_function_of_its_inputs() {
        let spec = ChaosSpec {
            dropout_per_round: 0.5,
            stale_prob: 0.5,
            slow_prob: 0.5,
            ..ChaosSpec::default()
        };
        for client in 0..50 {
            for t0 in [0usize, 17, 240] {
                let a = spec.round_plan(7, client, t0, 30, 1.0);
                let b = spec.round_plan(7, client, t0, 30, 1.0);
                assert_eq!(a, b);
            }
        }
    }

    #[test]
    fn plans_differ_across_clients_rounds_and_seeds() {
        let spec = ChaosSpec { dropout_per_round: 1.0, ..ChaosSpec::default() };
        let base = spec.round_plan(7, 0, 0, 30, 1.0);
        let mut distinct = 0;
        for (seed, client, t0) in [(7u64, 1usize, 0usize), (7, 0, 30), (8, 0, 0)] {
            if spec.round_plan(seed, client, t0, 30, 1.0) != base {
                distinct += 1;
            }
        }
        assert!(distinct >= 2, "independent streams should decorrelate plans");
    }

    #[test]
    fn zero_probability_spec_injects_nothing() {
        let spec = ChaosSpec {
            dropout_per_round: 0.0,
            stale_prob: 0.0,
            slow_prob: 0.0,
            ..ChaosSpec::default()
        };
        for client in 0..20 {
            assert_eq!(spec.round_plan(3, client, 100, 30, 1.0), SlotChaos::NONE);
        }
    }

    #[test]
    fn certain_faults_always_fire_within_bounds() {
        let spec = ChaosSpec {
            dropout_per_round: 1.0,
            stale_prob: 1.0,
            slow_prob: 1.0,
            slow_factor: 0.25,
            ..ChaosSpec::default()
        };
        for client in 0..20 {
            let p = spec.round_plan(11, client, 60, 30, 1.0);
            let (off, len) = p.drop_window.expect("dropout_per_round = 1");
            assert!(off < 30);
            assert!(len >= 1);
            assert!(p.submit_delay >= 1);
            assert_eq!(p.slow, 0.25);
        }
    }

    #[test]
    fn json_roundtrip_and_validation() {
        let j = Json::parse(
            r#"{"dropout_per_round": 0.3, "mean_drop_min": 5.0, "stale_prob": 1.0,
                "mean_delay_min": 2.0, "slow_prob": 0.2, "slow_factor": 0.8}"#,
        )
        .unwrap();
        let spec = ChaosSpec::from_json(&j).unwrap();
        assert_eq!(spec.dropout_per_round, 0.3);
        assert_eq!(spec.slow_factor, 0.8);
        // defaults fill missing keys — crash_prob included, so legacy
        // specs without the key keep meaning "no coordinator crashes"
        let spec = ChaosSpec::from_json(&Json::parse("{}").unwrap()).unwrap();
        assert_eq!(spec, ChaosSpec::default());
        assert_eq!(spec.crash_prob, 0.0);
        // validation rejects nonsense
        assert!(ChaosSpec::from_json(
            &Json::parse(r#"{"dropout_per_round": 1.5}"#).unwrap()
        )
        .is_err());
        assert!(
            ChaosSpec::from_json(&Json::parse(r#"{"slow_factor": 0.0}"#).unwrap()).is_err()
        );
        assert!(
            ChaosSpec::from_json(&Json::parse(r#"{"mean_drop_min": -1}"#).unwrap()).is_err()
        );
        // crash_prob is bounds-checked like every other probability
        let err = ChaosSpec::from_json(&Json::parse(r#"{"crash_prob": 1.5}"#).unwrap())
            .unwrap_err();
        assert!(err.to_string().contains("crash_prob"), "{err}");
        assert!(
            ChaosSpec::from_json(&Json::parse(r#"{"crash_prob": -0.1}"#).unwrap()).is_err()
        );
        let spec =
            ChaosSpec::from_json(&Json::parse(r#"{"crash_prob": 0.5}"#).unwrap()).unwrap();
        assert_eq!(spec.crash_prob, 0.5);
    }

    #[test]
    fn crash_draw_is_pure_bounded_and_on_its_own_stream() {
        let spec = ChaosSpec { crash_prob: 1.0, ..ChaosSpec::default() };
        for seed in 0..40u64 {
            let a = spec.draw_crash(seed, 600);
            assert_eq!(a, spec.draw_crash(seed, 600), "draw must be pure");
            let t = a.expect("crash_prob = 1 must always crash");
            assert!((1..600).contains(&t), "crash step {t} out of [1, 600)");
        }
        // disarmed spec never crashes; degenerate horizons never crash
        let off = ChaosSpec::default();
        assert_eq!(off.crash_prob, 0.0);
        assert_eq!(off.draw_crash(7, 600), None);
        assert_eq!(spec.draw_crash(7, 1), None);
        // arming the crash stream must not move any per-client plan
        let armed = ChaosSpec { crash_prob: 1.0, ..ChaosSpec::default() };
        for client in 0..20 {
            assert_eq!(
                off.round_plan(9, client, 60, 30, 1.0),
                armed.round_plan(9, client, 60, 30, 1.0),
                "crash draw leaked into the per-client chaos stream"
            );
        }
        // a fractional probability crashes some seeds and spares others
        let half = ChaosSpec { crash_prob: 0.5, ..ChaosSpec::default() };
        let fired = (0..64u64).filter(|&s| half.draw_crash(s, 600).is_some()).count();
        assert!((10..=54).contains(&fired), "crash_prob 0.5 fired {fired}/64");
    }

    #[test]
    fn crash_fault_error_is_downcastable() {
        let err: anyhow::Error = CrashFault { at: 42 }.into();
        let cf = err.downcast_ref::<CrashFault>().expect("downcast");
        assert_eq!(cf.at, 42);
        assert!(err.to_string().contains("step 42"));
    }
}
