//! Battery storage extension (paper §7 future work: "explicitly taking
//! energy storage ... into account").
//!
//! A simple but physically honest model: capacity-limited state of charge,
//! separate charge/discharge power limits, round-trip efficiency split
//! between the two directions, and a cycle-throughput counter as the aging
//! proxy the paper cites ([36]: frequent charge cycles accelerate aging).
//!
//! Integration: a power domain with a battery buffers excess energy that
//! clients cannot absorb in a step and releases it in later steps; the
//! ablation bench (`cargo bench --bench ablation`) quantifies how much a
//! small buffer narrows the gap between FedZero and the unconstrained
//! upper bound.

#[derive(Clone, Debug)]
pub struct Battery {
    /// usable capacity, Wh
    pub capacity_wh: f64,
    /// max charge energy per step, Wh
    pub max_charge_wh: f64,
    /// max discharge energy per step, Wh
    pub max_discharge_wh: f64,
    /// one-way charge efficiency (0, 1]
    pub charge_eff: f64,
    /// one-way discharge efficiency (0, 1]
    pub discharge_eff: f64,
    /// current state of charge, Wh
    pub soc_wh: f64,
    /// lifetime energy throughput (aging proxy), Wh
    pub throughput_wh: f64,
}

impl Battery {
    /// A battery with the given capacity and a C/2 power limit, 95%/95%
    /// one-way efficiencies (≈90% round trip, typical Li-ion).
    pub fn new(capacity_wh: f64) -> Battery {
        Battery {
            capacity_wh,
            max_charge_wh: capacity_wh / 2.0,
            max_discharge_wh: capacity_wh / 2.0,
            charge_eff: 0.95,
            discharge_eff: 0.95,
            soc_wh: 0.0,
            throughput_wh: 0.0,
        }
    }

    /// Offer `surplus_wh` for charging; returns the energy actually drawn
    /// from the source (≥ stored, due to charge losses).
    pub fn charge(&mut self, surplus_wh: f64) -> f64 {
        if surplus_wh <= 0.0 || self.soc_wh >= self.capacity_wh {
            return 0.0;
        }
        let room = self.capacity_wh - self.soc_wh;
        // drawing d from the source stores d * eff
        let draw = surplus_wh
            .min(self.max_charge_wh)
            .min(room / self.charge_eff);
        self.soc_wh += draw * self.charge_eff;
        self.throughput_wh += draw * self.charge_eff;
        draw
    }

    /// Request `want_wh` of delivered energy; returns what the battery
    /// actually delivers (≤ want, limited by SoC, power limit, losses).
    pub fn discharge(&mut self, want_wh: f64) -> f64 {
        if want_wh <= 0.0 || self.soc_wh <= 0.0 {
            return 0.0;
        }
        // delivering d drains d / eff from the cells
        let deliverable = (self.soc_wh * self.discharge_eff)
            .min(self.max_discharge_wh)
            .min(want_wh);
        self.soc_wh -= deliverable / self.discharge_eff;
        self.soc_wh = self.soc_wh.max(0.0);
        self.throughput_wh += deliverable / self.discharge_eff;
        deliverable
    }

    /// equivalent full cycles so far (aging proxy)
    pub fn equivalent_cycles(&self) -> f64 {
        if self.capacity_wh <= 0.0 {
            0.0
        } else {
            self.throughput_wh / (2.0 * self.capacity_wh)
        }
    }

    pub fn round_trip_efficiency(&self) -> f64 {
        self.charge_eff * self.discharge_eff
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;
    use crate::util::rng::Rng;

    #[test]
    fn charge_respects_capacity_and_losses() {
        let mut b = Battery::new(100.0);
        let drawn = b.charge(30.0);
        assert!((drawn - 30.0).abs() < 1e-9);
        assert!((b.soc_wh - 28.5).abs() < 1e-9); // 30 * 0.95
        // fill to the brim
        let mut total = drawn;
        for _ in 0..20 {
            total += b.charge(50.0);
        }
        assert!(b.soc_wh <= 100.0 + 1e-9);
        // energy conservation: stored = drawn * eff
        assert!((total * 0.95 - b.soc_wh).abs() < 1e-6);
    }

    #[test]
    fn discharge_respects_soc_and_losses() {
        let mut b = Battery::new(100.0);
        b.soc_wh = 50.0;
        let got = b.discharge(1000.0);
        // limited by max_discharge (50) and soc*eff (47.5)
        assert!((got - 47.5).abs() < 1e-9);
        assert!(b.soc_wh.abs() < 1e-9);
        assert_eq!(b.discharge(10.0), 0.0);
    }

    #[test]
    fn power_limits_enforced() {
        let mut b = Battery::new(100.0);
        b.max_charge_wh = 5.0;
        assert!((b.charge(50.0) - 5.0).abs() < 1e-9);
        b.soc_wh = 100.0;
        b.max_discharge_wh = 7.0;
        assert!((b.discharge(50.0) - 7.0).abs() < 1e-9);
    }

    #[test]
    fn cycle_counter_accumulates() {
        let mut b = Battery::new(10.0);
        for _ in 0..10 {
            b.charge(5.0);
            b.discharge(5.0);
        }
        assert!(b.equivalent_cycles() > 1.0);
    }

    #[test]
    fn prop_soc_always_in_bounds_and_no_free_energy() {
        forall(200, |rng: &mut Rng| {
            let mut b = Battery::new(rng.range_f64(1.0, 200.0));
            let mut drawn_total = 0.0;
            let mut delivered_total = 0.0;
            for _ in 0..100 {
                if rng.bool(0.5) {
                    drawn_total += b.charge(rng.range_f64(0.0, 60.0));
                } else {
                    delivered_total += b.discharge(rng.range_f64(0.0, 60.0));
                }
                assert!(b.soc_wh >= -1e-9 && b.soc_wh <= b.capacity_wh + 1e-9);
            }
            // can never deliver more than round-trip efficiency of input
            assert!(
                delivered_total
                    <= drawn_total * b.round_trip_efficiency() + 1e-6,
                "free energy: in {drawn_total} out {delivered_total}"
            );
        });
    }
}
