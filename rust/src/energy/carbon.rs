//! Grid carbon-intensity extension (paper §3.3 / §4.3: environments where
//! excess energy is not always available "need to default to a less
//! radical approach and consider carbon-intensive grid energy at times";
//! §7 lists grid carbon intensity as future work).
//!
//! Provides a synthetic gCO₂/kWh trace with the structure of real grids
//! (diurnal swing — solar noon dip, evening ramp — plus slow weather
//! drift) and a carbon ledger. The `relaxed` FedZero mode uses it: when
//! Algorithm 1 finds no feasible selection at d_max, the round may fall
//! back to grid energy and the ledger prices its emissions.

use crate::util::rng::Rng;

/// Synthetic grid carbon-intensity series, gCO₂eq/kWh per step.
pub fn carbon_intensity_series(
    steps: usize,
    step_minutes: f64,
    base_g_per_kwh: f64,
    seed: u64,
) -> Vec<f64> {
    let mut rng = Rng::new(seed ^ 0xC02);
    let mut drift = 0.0f64;
    let alpha = (-step_minutes / 600.0f64).exp(); // ~10 h weather drift
    (0..steps)
        .map(|i| {
            let h = (i as f64 * step_minutes / 60.0).rem_euclid(24.0);
            // solar dip around noon, evening peak around 19:00
            let solar_dip =
                -0.25 * (-((h - 13.0) / 3.5).powi(2)).exp();
            let evening_peak = 0.2 * (-((h - 19.5) / 2.5).powi(2)).exp();
            drift = alpha * drift + (1.0 - alpha) * 0.1 * rng.normal();
            (base_g_per_kwh * (1.0 + solar_dip + evening_peak + drift))
                .max(20.0)
        })
        .collect()
}

/// Carbon bookkeeping for runs that may touch grid energy.
#[derive(Clone, Debug, Default)]
pub struct CarbonLedger {
    /// kWh drawn from (zero-carbon) excess energy
    pub excess_kwh: f64,
    /// kWh drawn from the grid
    pub grid_kwh: f64,
    /// accumulated emissions, gCO₂eq
    pub emissions_g: f64,
}

impl CarbonLedger {
    pub fn record_excess(&mut self, wh: f64) {
        self.excess_kwh += wh / 1000.0;
    }

    pub fn record_grid(&mut self, wh: f64, intensity_g_per_kwh: f64) {
        self.grid_kwh += wh / 1000.0;
        self.emissions_g += wh / 1000.0 * intensity_g_per_kwh;
    }

    pub fn total_kwh(&self) -> f64 {
        self.excess_kwh + self.grid_kwh
    }

    /// operational emissions in kg CO₂eq
    pub fn emissions_kg(&self) -> f64 {
        self.emissions_g / 1000.0
    }

    /// fraction of energy that was zero-carbon
    pub fn excess_share(&self) -> f64 {
        if self.total_kwh() <= 0.0 {
            1.0
        } else {
            self.excess_kwh / self.total_kwh()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::stats;

    #[test]
    fn series_has_noon_dip_and_evening_peak() {
        let s = carbon_intensity_series(7 * 1440, 1.0, 400.0, 1);
        let minute_mean = |min: usize| -> f64 {
            (0..7).map(|d| s[d * 1440 + min]).sum::<f64>() / 7.0
        };
        let noon = minute_mean(13 * 60);
        let evening = minute_mean(19 * 60 + 30);
        let night = minute_mean(3 * 60);
        assert!(noon < night, "noon {noon} !< night {night}");
        assert!(evening > noon, "evening {evening} !> noon {noon}");
    }

    #[test]
    fn series_is_positive_and_bounded() {
        let s = carbon_intensity_series(2000, 1.0, 300.0, 2);
        assert!(stats::min(&s) >= 20.0);
        assert!(stats::max(&s) < 900.0);
    }

    #[test]
    fn ledger_accounts_correctly() {
        let mut l = CarbonLedger::default();
        l.record_excess(500.0);
        l.record_grid(250.0, 400.0);
        assert!((l.total_kwh() - 0.75).abs() < 1e-12);
        assert!((l.emissions_kg() - 0.1).abs() < 1e-12);
        assert!((l.excess_share() - 2.0 / 3.0).abs() < 1e-12);
    }

    #[test]
    fn zero_energy_is_fully_clean() {
        let l = CarbonLedger::default();
        assert_eq!(l.excess_share(), 1.0);
        assert_eq!(l.emissions_kg(), 0.0);
    }
}
