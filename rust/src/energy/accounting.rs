//! Energy bookkeeping: Wh consumed per client / domain / round, the basis
//! of the paper's energy-to-accuracy metric (Table 3) and the fairness
//! analyses (Fig 6).

#[derive(Clone, Debug, Default)]
pub struct EnergyMeter {
    per_client_wh: Vec<f64>,
    per_domain_wh: Vec<f64>,
    per_round_wh: Vec<f64>,
    total_wh: f64,
}

impl EnergyMeter {
    pub fn new(n_clients: usize, n_domains: usize) -> Self {
        EnergyMeter {
            per_client_wh: vec![0.0; n_clients],
            per_domain_wh: vec![0.0; n_domains],
            per_round_wh: Vec::new(),
            total_wh: 0.0,
        }
    }

    pub fn begin_round(&mut self) {
        self.per_round_wh.push(0.0);
    }

    pub fn record(&mut self, client: usize, domain: usize, wh: f64) {
        debug_assert!(wh >= 0.0);
        self.per_client_wh[client] += wh;
        self.per_domain_wh[domain] += wh;
        if let Some(r) = self.per_round_wh.last_mut() {
            *r += wh;
        }
        self.total_wh += wh;
    }

    pub fn total_kwh(&self) -> f64 {
        self.total_wh / 1000.0
    }

    pub fn client_wh(&self, client: usize) -> f64 {
        self.per_client_wh[client]
    }

    pub fn domain_wh(&self, domain: usize) -> f64 {
        self.per_domain_wh[domain]
    }

    pub fn round_wh(&self, round: usize) -> f64 {
        self.per_round_wh.get(round).copied().unwrap_or(0.0)
    }

    pub fn rounds(&self) -> usize {
        self.per_round_wh.len()
    }

    /// Checkpoint view of every tally (client Wh, domain Wh, round Wh,
    /// total Wh) — [`EnergyMeter::restore`] rebuilds the meter exactly.
    pub fn snapshot(&self) -> (&[f64], &[f64], &[f64], f64) {
        (&self.per_client_wh, &self.per_domain_wh, &self.per_round_wh, self.total_wh)
    }

    /// Rebuild a meter from an [`EnergyMeter::snapshot`] capture.
    pub fn restore(
        per_client_wh: Vec<f64>,
        per_domain_wh: Vec<f64>,
        per_round_wh: Vec<f64>,
        total_wh: f64,
    ) -> Self {
        EnergyMeter { per_client_wh, per_domain_wh, per_round_wh, total_wh }
    }

    /// cumulative kWh up to and including `round`
    pub fn cumulative_kwh(&self, round: usize) -> f64 {
        self.per_round_wh[..=round.min(self.per_round_wh.len().saturating_sub(1))]
            .iter()
            .sum::<f64>()
            / 1000.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tallies_roll_up() {
        let mut m = EnergyMeter::new(3, 2);
        m.begin_round();
        m.record(0, 0, 100.0);
        m.record(1, 1, 50.0);
        m.begin_round();
        m.record(0, 0, 25.0);
        assert_eq!(m.client_wh(0), 125.0);
        assert_eq!(m.client_wh(2), 0.0);
        assert_eq!(m.domain_wh(1), 50.0);
        assert_eq!(m.round_wh(0), 150.0);
        assert_eq!(m.round_wh(1), 25.0);
        assert!((m.total_kwh() - 0.175).abs() < 1e-12);
        assert!((m.cumulative_kwh(0) - 0.15).abs() < 1e-12);
        assert!((m.cumulative_kwh(1) - 0.175).abs() < 1e-12);
        assert_eq!(m.rounds(), 2);
    }
}
