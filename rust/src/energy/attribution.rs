//! Runtime power sharing within a power domain (paper §4.5).
//!
//! When several participating clients share one domain's excess energy,
//! the domain controller attributes power in two steps:
//!
//!  1. clients below their minimum participation m_min get power first,
//!     weighted by the energy still required to reach the threshold,
//!     δ_c·(m_min − m_comp);
//!  2. leftover power goes to clients below m_max, weighted by
//!     δ_c·(m_max − m_comp).
//!
//! Each step is a capped proportional water-filling: a client can absorb
//! at most `usable_wh` (its spare compute this timestep × δ), so freed
//! shares are redistributed among unsaturated clients until exhausted.

const EPS: f64 = 1e-12;

/// One participating client's demand at this timestep.
#[derive(Clone, Debug)]
pub struct PowerRequest {
    /// δ_c · max(0, m_min − m_comp): energy still needed to reach minimum
    pub need_min_wh: f64,
    /// δ_c · max(0, m_max − m_comp): energy usable up to the maximum
    pub need_max_wh: f64,
    /// δ_c · min(spare_{c,t}, m_max − m_comp): what the client can
    /// physically absorb this step (capacity constraint)
    pub usable_wh: f64,
}

/// Capped proportional allocation: distribute `available` across clients
/// proportionally to `weights`, never exceeding `caps`, redistributing
/// freed remainder. Returns per-client allocation.
pub fn waterfill(available: f64, weights: &[f64], caps: &[f64]) -> Vec<f64> {
    assert_eq!(weights.len(), caps.len());
    let n = weights.len();
    let mut alloc = vec![0.0; n];
    let mut remaining = available.max(0.0);
    let mut active: Vec<usize> = (0..n)
        .filter(|&i| weights[i] > EPS && caps[i] > EPS)
        .collect();
    while remaining > EPS && !active.is_empty() {
        let wsum: f64 = active.iter().map(|&i| weights[i]).sum();
        if wsum <= EPS {
            break;
        }
        let mut saturated = Vec::new();
        let mut distributed = 0.0;
        for &i in &active {
            let share = remaining * weights[i] / wsum;
            let take = share.min(caps[i] - alloc[i]);
            alloc[i] += take;
            distributed += take;
            if caps[i] - alloc[i] <= EPS {
                saturated.push(i);
            }
        }
        remaining -= distributed;
        if saturated.is_empty() || distributed <= EPS {
            break; // all got full proportional share; done
        }
        active.retain(|i| !saturated.contains(i));
    }
    alloc
}

/// Two-step attribution. Returns Wh granted to each client.
pub fn attribute_power(available_wh: f64, reqs: &[PowerRequest]) -> Vec<f64> {
    let n = reqs.len();
    if n == 0 || available_wh <= EPS {
        return vec![0.0; n];
    }
    // Step 1: minimum thresholds first.
    let w1: Vec<f64> = reqs.iter().map(|r| r.need_min_wh.max(0.0)).collect();
    let c1: Vec<f64> = reqs
        .iter()
        .map(|r| r.need_min_wh.max(0.0).min(r.usable_wh.max(0.0)))
        .collect();
    let step1 = waterfill(available_wh, &w1, &c1);
    let used1: f64 = step1.iter().sum();

    // Step 2: leftover toward maxima.
    let w2: Vec<f64> = reqs
        .iter()
        .zip(&step1)
        .map(|(r, &got)| (r.need_max_wh - got).max(0.0))
        .collect();
    let c2: Vec<f64> = reqs
        .iter()
        .zip(&step1)
        .map(|(r, &got)| (r.usable_wh - got).max(0.0).min((r.need_max_wh - got).max(0.0)))
        .collect();
    let step2 = waterfill(available_wh - used1, &w2, &c2);

    step1.iter().zip(&step2).map(|(a, b)| a + b).collect()
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::util::prop::forall;

    fn req(min: f64, max: f64, usable: f64) -> PowerRequest {
        PowerRequest { need_min_wh: min, need_max_wh: max, usable_wh: usable }
    }

    #[test]
    fn single_client_takes_what_it_can_use() {
        let a = attribute_power(10.0, &[req(2.0, 8.0, 5.0)]);
        assert!((a[0] - 5.0).abs() < 1e-9); // capacity-limited
        let b = attribute_power(3.0, &[req(2.0, 8.0, 5.0)]);
        assert!((b[0] - 3.0).abs() < 1e-9); // energy-limited
    }

    #[test]
    fn minimums_have_priority() {
        // client 0 needs 4 to reach min; client 1 already past min.
        // available 4 -> all of it goes to client 0.
        let a = attribute_power(
            4.0,
            &[req(4.0, 10.0, 10.0), req(0.0, 10.0, 10.0)],
        );
        assert!((a[0] - 4.0).abs() < 1e-9, "{a:?}");
        assert!(a[1].abs() < 1e-9);
    }

    #[test]
    fn step1_weighted_by_remaining_need() {
        // both below min; needs 6 vs 2; available 4 -> 3 vs 1
        let a = attribute_power(
            4.0,
            &[req(6.0, 10.0, 10.0), req(2.0, 10.0, 10.0)],
        );
        assert!((a[0] - 3.0).abs() < 1e-9, "{a:?}");
        assert!((a[1] - 1.0).abs() < 1e-9, "{a:?}");
    }

    #[test]
    fn leftover_flows_to_step2() {
        // minimums take 2+2, leftover 6 split by remaining max-need 8 vs 4
        let a = attribute_power(
            10.0,
            &[req(2.0, 10.0, 100.0), req(2.0, 6.0, 100.0)],
        );
        assert!((a[0] - 2.0 - 4.0).abs() < 1e-9, "{a:?}");
        assert!((a[1] - 2.0 - 2.0).abs() < 1e-9, "{a:?}");
    }

    #[test]
    fn capacity_caps_redistribute() {
        // equal weights but client 0 can only absorb 1; client 1 takes rest
        let a = attribute_power(
            8.0,
            &[req(4.0, 4.0, 1.0), req(4.0, 8.0, 100.0)],
        );
        assert!((a[0] - 1.0).abs() < 1e-9, "{a:?}");
        assert!((a[1] - 7.0).abs() < 1e-9, "{a:?}");
    }

    #[test]
    fn waterfill_zero_weights_get_nothing() {
        let a = waterfill(10.0, &[0.0, 1.0], &[5.0, 5.0]);
        assert_eq!(a[0], 0.0);
        assert!((a[1] - 5.0).abs() < 1e-9);
    }

    #[test]
    fn prop_conservation_and_caps() {
        forall(300, |rng| {
            let n = rng.range(1, 7);
            let reqs: Vec<PowerRequest> = (0..n)
                .map(|_| {
                    let min = rng.range_f64(0.0, 5.0);
                    let max = min + rng.range_f64(0.0, 8.0);
                    PowerRequest {
                        need_min_wh: min,
                        need_max_wh: max,
                        usable_wh: rng.range_f64(0.0, 10.0),
                    }
                })
                .collect();
            let available = rng.range_f64(0.0, 20.0);
            let alloc = attribute_power(available, &reqs);
            let total: f64 = alloc.iter().sum();
            // never over-allocate the domain budget
            assert!(total <= available + 1e-6, "total {total} > {available}");
            for (a, r) in alloc.iter().zip(&reqs) {
                assert!(*a >= -1e-9);
                // capacity and max-participation caps respected
                assert!(*a <= r.usable_wh + 1e-6);
                assert!(*a <= r.need_max_wh + 1e-6);
            }
            // work-conserving: if energy remains, every client is saturated
            let absorbable: f64 = reqs
                .iter()
                .map(|r| r.usable_wh.min(r.need_max_wh))
                .sum();
            if available > absorbable + 1e-6 {
                for (a, r) in alloc.iter().zip(&reqs) {
                    let cap = r.usable_wh.min(r.need_max_wh);
                    assert!(
                        *a >= cap - 1e-6,
                        "unsaturated client with spare energy"
                    );
                }
            }
        });
    }

    #[test]
    fn prop_minimums_met_when_energy_suffices() {
        forall(300, |rng| {
            let n = rng.range(1, 6);
            let reqs: Vec<PowerRequest> = (0..n)
                .map(|_| {
                    let min = rng.range_f64(0.0, 4.0);
                    PowerRequest {
                        need_min_wh: min,
                        need_max_wh: min + rng.range_f64(0.0, 5.0),
                        // usable always covers the min here
                        usable_wh: min + rng.range_f64(0.0, 5.0),
                    }
                })
                .collect();
            let total_min: f64 = reqs.iter().map(|r| r.need_min_wh).sum();
            let available = total_min + rng.range_f64(0.0, 5.0);
            let alloc = attribute_power(available, &reqs);
            for (a, r) in alloc.iter().zip(&reqs) {
                assert!(
                    *a >= r.need_min_wh - 1e-6,
                    "minimum unmet: {a} < {}",
                    r.need_min_wh
                );
            }
        });
    }
}
