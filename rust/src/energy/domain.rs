//! Power domains: disjoint groups of clients sharing one source of
//! renewable excess energy (paper §3.1), each with an actual power trace
//! and a forecaster queried by the server at round start.

use crate::trace::forecast::SeriesForecaster;

#[derive(Clone, Debug)]
pub struct PowerDomain {
    pub id: usize,
    pub name: String,
    /// nameplate capacity in W (the paper's domains: 800 W)
    pub capacity_w: f64,
    /// actual excess power per step, W
    pub power_w: Vec<f64>,
    /// forecaster over the same series (may be perfect/realistic)
    pub forecaster: SeriesForecaster,
    /// step duration in minutes (converts W to Wh per step)
    pub step_minutes: f64,
    /// experiment knob: unlimited energy (paper's Berlin-unlimited, Fig 6b)
    pub unlimited: bool,
}

impl PowerDomain {
    pub fn new(
        id: usize,
        name: &str,
        capacity_w: f64,
        power_w: Vec<f64>,
        forecaster: SeriesForecaster,
        step_minutes: f64,
    ) -> Self {
        PowerDomain {
            id,
            name: name.to_string(),
            capacity_w,
            power_w,
            forecaster,
            step_minutes,
            unlimited: false,
        }
    }

    /// actual excess energy available in step `t`, Wh
    pub fn energy_wh(&self, t: usize) -> f64 {
        if self.unlimited {
            return f64::INFINITY;
        }
        self.power_w.get(t).copied().unwrap_or(0.0) * self.step_minutes / 60.0
    }

    /// forecast excess energy for step `t` issued at `t0`, Wh — the
    /// per-column fetch behind the simulator's forecast ring
    /// (`selection::ring`): one call per domain per idle step when the
    /// window advances, a full window's worth on re-anchoring
    #[inline]
    pub fn forecast_energy_wh(&self, t0: usize, t: usize) -> f64 {
        if self.unlimited {
            // forecasting infinite energy confuses the MIP scaling; expose
            // a very large but finite budget instead
            return self.capacity_w.max(1.0) * self.step_minutes / 60.0 * 1e6;
        }
        self.forecaster.forecast(t0, t) * self.step_minutes / 60.0
    }

    /// forecast window [t0, t0+h) in Wh per step
    pub fn forecast_window_wh(&self, t0: usize, horizon: usize) -> Vec<f64> {
        let mut out = Vec::new();
        self.forecast_window_wh_into(t0, horizon, &mut out);
        out
    }

    /// [`Self::forecast_window_wh`] into a reused buffer (§Perf: the
    /// simulator refreshes every domain's window each selection attempt;
    /// writing in place keeps the steady state allocation-free).
    pub fn forecast_window_wh_into(&self, t0: usize, horizon: usize, out: &mut Vec<f64>) {
        out.clear();
        out.extend((t0..t0 + horizon).map(|t| self.forecast_energy_wh(t0, t)));
    }

    /// does the domain currently produce any excess power?
    pub fn has_power(&self, t: usize) -> bool {
        self.energy_wh(t) > 1e-9
    }

    pub fn horizon(&self) -> usize {
        self.power_w.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::trace::forecast::SeriesForecaster;

    fn domain(power: Vec<f64>) -> PowerDomain {
        let f = SeriesForecaster::perfect(power.clone());
        PowerDomain::new(0, "test", 800.0, power, f, 1.0)
    }

    #[test]
    fn energy_conversion_w_to_wh() {
        let d = domain(vec![600.0, 0.0]);
        assert!((d.energy_wh(0) - 10.0).abs() < 1e-12); // 600 W for 1 min
        assert_eq!(d.energy_wh(1), 0.0);
        assert_eq!(d.energy_wh(99), 0.0); // out of range
        assert!(d.has_power(0));
        assert!(!d.has_power(1));
    }

    #[test]
    fn perfect_forecast_equals_actual() {
        let d = domain(vec![120.0, 240.0, 360.0]);
        for t in 0..3 {
            assert!((d.forecast_energy_wh(0, t) - d.energy_wh(t)).abs() < 1e-12);
        }
        let w = d.forecast_window_wh(0, 3);
        assert_eq!(w.len(), 3);
        assert!((w[2] - 6.0).abs() < 1e-12);
    }

    #[test]
    fn unlimited_domain() {
        let mut d = domain(vec![0.0; 5]);
        d.unlimited = true;
        assert!(d.energy_wh(2).is_infinite());
        assert!(d.forecast_energy_wh(0, 2) > 1e6);
        assert!(d.has_power(4));
    }
}
