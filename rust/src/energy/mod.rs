//! Energy subsystem: power domains, runtime power attribution, and energy
//! accounting — the Vessim-equivalent substrate plus the paper's §4.5
//! runtime power-sharing contribution.

pub mod accounting;
pub mod battery;
pub mod carbon;
pub mod attribution;
pub mod domain;

pub use accounting::EnergyMeter;
pub use battery::Battery;
pub use carbon::CarbonLedger;
pub use attribution::{attribute_power, waterfill, PowerRequest};
pub use domain::PowerDomain;
