//! # FedZero — paper reproduction
//!
//! A three-layer Rust + JAX + Pallas reproduction of *FedZero: Leveraging
//! Renewable Excess Energy in Federated Learning* (Wiesner et al.,
//! ACM e-Energy '24). The Rust layer hosts the paper's contribution —
//! energy-aware client selection and runtime power sharing — plus the full
//! evaluation substrate (energy simulator, trace models, MIP solvers, FL
//! server, metrics); the compute path executes AOT-compiled JAX/Pallas
//! HLO artifacts through PJRT. See DESIGN.md for the system inventory.
pub mod client;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod energy;
pub mod fl;
pub mod metrics;
pub mod runtime;
pub mod scenario;
pub mod selection;
pub mod sim;
pub mod solver;
pub mod trace;
pub mod util;
