//! Observability integration tests (ISSUE 10): the telemetry layer's
//! determinism contract end to end. Enabling counters, histograms and
//! span tracing must not change a single byte of any deterministic
//! output — metrics, journal bytes, snapshot files, campaign reports —
//! at any worker count; and the exporters must produce well-formed
//! Chrome-trace and TELEMETRY.json documents fed by the real pipeline.

use std::path::PathBuf;
use std::sync::Mutex;

use fedzero::coordinator::{run_experiment, ExperimentSpec, StrategyKind};
use fedzero::metrics::MetricsLog;
use fedzero::scenario::campaign::{run_campaign, CampaignSpec};
use fedzero::scenario::EnvSpec;
use fedzero::sim::ChaosSpec;
use fedzero::util::json::Json;
use fedzero::util::obs;
use fedzero::util::par;

// obs state is process-global; every test in this binary serialises on
// this lock and leaves telemetry disabled + reset on exit
static OBS_LOCK: Mutex<()> = Mutex::new(());

fn lock() -> std::sync::MutexGuard<'static, ()> {
    OBS_LOCK.lock().unwrap_or_else(|e| e.into_inner())
}

fn mock_spec(seed: u64, ckpt: Option<PathBuf>) -> ExperimentSpec {
    ExperimentSpec {
        use_mock: true,
        days: 1,
        n_clients: 20,
        n_per_round: 4,
        d_max: 30,
        preset: "tiny".into(),
        dataset_scale: 0.2,
        seed,
        env: Some(EnvSpec {
            // a little chaos so the fault counters and the stale-fence
            // path are exercised by the identity check too
            chaos: Some(ChaosSpec {
                dropout_per_round: 0.2,
                stale_prob: 0.2,
                ..ChaosSpec::default()
            }),
            ..EnvSpec::global()
        }),
        checkpoint_dir: ckpt,
        snapshot_every: 3,
        ..Default::default()
    }
}

fn read_dir_sorted(dir: &std::path::Path) -> Vec<(String, Vec<u8>)> {
    let mut out: Vec<(String, Vec<u8>)> = std::fs::read_dir(dir)
        .unwrap()
        .map(|e| {
            let e = e.unwrap();
            (
                e.file_name().to_string_lossy().into_owned(),
                std::fs::read(e.path()).unwrap(),
            )
        })
        .collect();
    out.sort();
    out
}

/// The tentpole acceptance criterion at the experiment level: telemetry
/// ON (counters + histograms + span tracing) produces bit-identical
/// metrics, journal bytes and snapshot files to telemetry OFF.
#[test]
fn telemetry_on_is_bit_identical_to_off() {
    let _g = lock();
    let base = std::env::temp_dir()
        .join(format!("fedzero_obs_{}_ident", std::process::id()));
    let (dir_off, dir_on) = (base.join("off"), base.join("on"));
    let _ = std::fs::remove_dir_all(&base);

    obs::set_enabled(false);
    obs::reset();
    let off = run_experiment(&mock_spec(11, Some(dir_off.clone()))).unwrap();

    obs::set_tracing(true); // arms counters AND span trace events
    obs::reset();
    let on = run_experiment(&mock_spec(11, Some(dir_on.clone()))).unwrap();

    // the full metrics log, f64 bits included (snapshot_json is the
    // lossless codec), plus the durable byte streams on disk
    assert_eq!(off.metrics, on.metrics);
    assert_eq!(
        off.metrics.snapshot_json().to_string_pretty(),
        on.metrics.snapshot_json().to_string_pretty()
    );
    assert_eq!(off.steps_executed, on.steps_executed);
    let files_off = read_dir_sorted(&dir_off);
    let files_on = read_dir_sorted(&dir_on);
    assert!(!files_off.is_empty(), "checkpoint dir is empty");
    assert_eq!(
        files_off.iter().map(|(n, _)| n).collect::<Vec<_>>(),
        files_on.iter().map(|(n, _)| n).collect::<Vec<_>>()
    );
    for ((name, off_bytes), (_, on_bytes)) in files_off.iter().zip(&files_on) {
        assert_eq!(
            off_bytes, on_bytes,
            "{name} diverged with telemetry on (journal/snapshot bytes \
             must be identical)"
        );
    }

    // and the run actually fed the probes: engine + journal at minimum
    let s = obs::snapshot();
    assert!(s.ctr(obs::Ctr::EngineRounds) > 0);
    assert!(s.ctr(obs::Ctr::JournalFrames) > 0);
    assert!(s.hist_count(obs::Hist::RoundNs) > 0);
    assert!(s.hist_count(obs::Hist::JournalAppendNs) > 0);

    obs::set_enabled(false);
    obs::reset();
    let _ = std::fs::remove_dir_all(&base);
}

/// Campaign-level identity: with telemetry armed, the report stays
/// byte-identical to the telemetry-off serial reference at 1, 2 and 8
/// workers (the ci.sh --quick gate mirrors this on the built binary).
#[test]
fn campaign_report_identical_with_telemetry_at_any_worker_count() {
    let _g = lock();
    let mut spec = CampaignSpec::smoke();
    spec.name = "obs-fixture".into();
    spec.seeds = vec![0, 1];
    spec.strategies = vec![StrategyKind::FedZero];

    obs::set_enabled(false);
    obs::reset();
    let reference = run_campaign(&spec, 1).unwrap().report_json().to_string_pretty();

    obs::set_enabled(true);
    obs::reset();
    for workers in [1usize, 2, 8] {
        let text = run_campaign(&spec, workers).unwrap().report_json().to_string_pretty();
        assert_eq!(
            text, reference,
            "report diverged with telemetry on at {workers} workers"
        );
    }
    let s = obs::snapshot();
    assert_eq!(s.ctr(obs::Ctr::CampaignCells), 3 * 2);
    assert!(s.ctr(obs::Ctr::EngineRounds) > 0);
    assert!(s.ctr(obs::Ctr::TreeAggregations) > 0);
    assert!(s.hist_count(obs::Hist::CellWallNs) > 0);

    obs::set_enabled(false);
    obs::reset();
}

/// TELEMETRY.json carries counters/histograms from all the instrumented
/// subsystems after a run that exercises them (engine, solver B&B, the
/// steal scheduler, tree aggregation, journal, chaos, campaign).
#[test]
fn telemetry_summary_covers_the_instrumented_subsystems() {
    let _g = lock();
    obs::set_enabled(true);
    obs::reset();

    // FedZero-exact drives the branch-and-bound solver; the checkpoint
    // feeds the journal; the chaos axis feeds the fault counters
    let dir = std::env::temp_dir()
        .join(format!("fedzero_obs_{}_sub", std::process::id()));
    let _ = std::fs::remove_dir_all(&dir);
    let mut spec = mock_spec(3, Some(dir.clone()));
    spec.strategy = StrategyKind::FedZeroExact;
    run_experiment(&spec).unwrap();
    // a guaranteed-parallel fan-out for the par section (small sims may
    // legitimately stay under the serial thresholds)
    par::steal::steal_exec(256, 4, |_| (), |_, _| {});

    let doc = obs::summary_json();
    assert_eq!(doc.get("schema").unwrap().as_str().unwrap(), "fedzero-telemetry-v1");
    let subs = doc.get("subsystems").unwrap();
    let nonzero = |sub: &str| -> bool {
        let sec = subs.get(sub).unwrap_or_else(|| panic!("missing section {sub}"));
        let ctrs = sec.get("counters").unwrap().as_obj().unwrap();
        let hists = sec.get("histograms").unwrap().as_obj().unwrap();
        ctrs.values().any(|v| v.as_f64().unwrap() > 0.0)
            || hists
                .values()
                .any(|h| h.get("count").unwrap().as_f64().unwrap() > 0.0)
    };
    let live: Vec<&str> = ["engine", "solver", "par", "tree", "journal", "chaos", "campaign"]
        .into_iter()
        .filter(|s| nonzero(s))
        .collect();
    assert!(
        live.len() >= 6,
        "expected >= 6 live subsystems, got {live:?}"
    );
    for sub in ["engine", "solver", "par", "tree", "journal"] {
        assert!(live.contains(&sub), "{sub} reported no activity: {live:?}");
    }

    obs::set_enabled(false);
    obs::reset();
    let _ = std::fs::remove_dir_all(&dir);
}

/// `--trace` produces a well-formed Chrome trace-event document with
/// nested per-round phase spans from a real run.
#[test]
fn trace_export_has_nested_round_phase_spans() {
    let _g = lock();
    obs::set_tracing(true);
    obs::reset();
    run_experiment(&mock_spec(7, None)).unwrap();

    let doc = obs::trace::trace_json();
    assert_eq!(doc.get("displayTimeUnit").unwrap().as_str().unwrap(), "ms");
    let evs = doc.get("traceEvents").unwrap().as_arr().unwrap();
    assert!(!evs.is_empty(), "no trace events recorded");
    for e in evs {
        assert_eq!(e.get("ph").unwrap().as_str().unwrap(), "X");
        assert_eq!(e.get("cat").unwrap().as_str().unwrap(), "fedzero");
        assert!(e.get("ts").unwrap().as_f64().unwrap() >= 0.0);
        assert!(e.get("dur").unwrap().as_f64().unwrap() >= 0.0);
        assert!(e.get("pid").is_some() && e.get("tid").is_some());
    }
    let name = |e: &Json| e.get("name").unwrap().as_str().unwrap().to_string();
    let names: Vec<String> = evs.iter().map(name).collect();
    for phase in ["round", "select", "aggregate", "eval"] {
        assert!(names.iter().any(|n| n == phase), "missing {phase} span");
    }
    // nesting: every round span's interval encloses at least one phase
    // child starting inside it
    let span_of = |e: &Json| -> (f64, f64) {
        (
            e.get("ts").unwrap().as_f64().unwrap(),
            e.get("dur").unwrap().as_f64().unwrap(),
        )
    };
    let rounds: Vec<(f64, f64)> =
        evs.iter().filter(|e| name(e) == "round").map(span_of).collect();
    let children: Vec<(f64, f64)> =
        evs.iter().filter(|e| name(e) == "aggregate").map(span_of).collect();
    assert!(!rounds.is_empty() && !children.is_empty());
    for (cts, cdur) in &children {
        assert!(
            rounds
                .iter()
                .any(|(rts, rdur)| rts <= cts && cts + cdur <= rts + rdur + 1e-3),
            "aggregate span at {cts} not enclosed by any round span"
        );
    }

    obs::set_enabled(false);
    obs::reset();
}

/// The MetricsLog/RoundRecord JSON round-trip on REAL run data (the
/// unit tests cover the hand-built fixture): snapshot_json is lossless
/// through parse + from_snapshot_json, f64 bits included.
#[test]
fn metrics_log_roundtrips_through_json_from_a_real_run() {
    let _g = lock();
    let report = run_experiment(&mock_spec(5, None)).unwrap();
    let m = &report.metrics;
    assert!(!m.rounds.is_empty() && !m.evals.is_empty());
    let text = m.snapshot_json().to_string_pretty();
    let parsed = Json::parse(&text).unwrap();
    let restored = MetricsLog::from_snapshot_json(&parsed).unwrap();
    assert_eq!(&restored, m, "snapshot codec lost information");
    // and the restored log re-serialises to the same bytes
    assert_eq!(restored.snapshot_json().to_string_pretty(), text);
}
