//! Integration: the AOT artifacts round-trip through PJRT with correct
//! numerics — the Rust-side counterpart of python/tests (which validate
//! the same functions against pure-jnp oracles before lowering).
//!
//! Requires `make artifacts` (tests no-op with a notice if missing).

use std::path::Path;

use fedzero::runtime::ModelRuntime;
use fedzero::util::rng::Rng;

fn runtime() -> Option<ModelRuntime> {
    match ModelRuntime::load(Path::new("artifacts"), "tiny") {
        Ok(rt) => Some(rt),
        Err(e) => {
            eprintln!("SKIP: tiny artifacts unavailable ({e:#}); run `make artifacts`");
            None
        }
    }
}

fn batch(rt: &ModelRuntime, seed: u64) -> (Vec<f32>, Vec<i32>) {
    let mut rng = Rng::new(seed);
    let b = rt.batch_size();
    let d = rt.manifest.input_dim;
    let x = (0..b * d).map(|_| rng.normal() as f32).collect();
    let y = (0..b)
        .map(|_| rng.below(rt.manifest.num_classes) as i32)
        .collect();
    (x, y)
}

#[test]
fn init_is_deterministic_and_seed_sensitive() {
    let Some(rt) = runtime() else { return };
    let a = rt.init_params(5).unwrap();
    let b = rt.init_params(5).unwrap();
    let c = rt.init_params(6).unwrap();
    assert_eq!(a.len(), rt.param_count());
    assert_eq!(a, b);
    assert_ne!(a, c);
    assert!(a.iter().all(|v| v.is_finite()));
}

#[test]
fn train_step_reduces_loss_on_fixed_batch() {
    let Some(rt) = runtime() else { return };
    let global = rt.init_params(1).unwrap();
    let (x, y) = batch(&rt, 2);
    let mut params = global.clone();
    let first = rt.train_step(&params, &global, &x, &y, 0.05, 0.01).unwrap();
    params = first.params.clone();
    let mut last = first.loss;
    for _ in 0..15 {
        let o = rt.train_step(&params, &global, &x, &y, 0.05, 0.01).unwrap();
        params = o.params;
        last = o.loss;
    }
    assert!(
        last < first.loss * 0.7,
        "loss did not decrease: {} -> {last}",
        first.loss
    );
}

#[test]
fn fedprox_mu_pulls_toward_global() {
    let Some(rt) = runtime() else { return };
    let global = rt.init_params(3).unwrap();
    let (x, y) = batch(&rt, 4);
    // big mu keeps params closer to global than mu=0
    let step = |mu: f32| {
        let mut p = global.clone();
        for _ in 0..10 {
            p = rt.train_step(&p, &global, &x, &y, 0.05, mu).unwrap().params;
        }
        p.iter()
            .zip(&global)
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>()
            .sqrt()
    };
    let drift_free = step(0.0);
    let drift_prox = step(0.5);
    assert!(
        drift_prox < drift_free,
        "proximal term did not bound drift: {drift_prox} >= {drift_free}"
    );
}

#[test]
fn eval_counts_are_bounded_and_consistent() {
    let Some(rt) = runtime() else { return };
    let params = rt.init_params(7).unwrap();
    let (x, y) = batch(&rt, 8);
    let (loss_sum, correct) = rt.eval_step(&params, &x, &y).unwrap();
    assert!(loss_sum > 0.0);
    assert!((0..=rt.batch_size() as i32).contains(&correct));
    // repeated eval is deterministic
    let again = rt.eval_step(&params, &x, &y).unwrap();
    assert_eq!(again.0, loss_sum);
    assert_eq!(again.1, correct);
}

#[test]
fn aggregate_matches_host_weighted_mean() {
    let Some(rt) = runtime() else { return };
    let a = rt.init_params(10).unwrap();
    let b = rt.init_params(11).unwrap();
    let out = rt.aggregate(&[a.as_slice(), b.as_slice()], &[3.0, 1.0]).unwrap();
    for i in 0..a.len() {
        let expect = (3.0 * a[i] + b[i]) / 4.0;
        assert!(
            (out[i] - expect).abs() < 1e-4 * (1.0 + expect.abs()),
            "index {i}: {} vs {expect}",
            out[i]
        );
    }
    // zero-padding invariance (fixed-K artifact)
    let padded = rt.aggregate(&[a.as_slice(), b.as_slice()], &[3.0, 1.0]).unwrap();
    assert_eq!(out, padded);
}

#[test]
fn evaluate_dataset_handles_partial_batches() {
    let Some(rt) = runtime() else { return };
    let params = rt.init_params(12).unwrap();
    let d = rt.manifest.input_dim;
    let b = rt.batch_size();
    let n = b + b / 2; // forces a trailing partial batch
    let mut rng = Rng::new(13);
    let xs: Vec<f32> = (0..n * d).map(|_| rng.normal() as f32).collect();
    let ys: Vec<i32> = (0..n)
        .map(|_| rng.below(rt.manifest.num_classes) as i32)
        .collect();
    let (acc, loss) = rt.evaluate_dataset(&params, &xs, &ys).unwrap();
    assert!((0.0..=1.0).contains(&acc), "acc={acc}");
    assert!(loss > 0.0 && loss.is_finite());
}
