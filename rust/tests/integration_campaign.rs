//! Campaign-level integration tests: the ISSUE-5 determinism gate
//! (byte-identical reports across worker counts) and end-to-end sweeps
//! over the new scenario axes (battery, churn, α, custom sites).

use fedzero::coordinator::StrategyKind;
use fedzero::scenario::campaign::{run_campaign, CampaignSpec};
use fedzero::scenario::{ChurnSpec, EnvSpec, SiteSet};
use fedzero::trace::solar::Site;
use fedzero::util::json::Json;

/// A 4-cell fixture that exercises two axes on top of the smoke spec.
fn small_spec() -> CampaignSpec {
    let mut spec = CampaignSpec::smoke();
    spec.name = "determinism-fixture".into();
    spec.n_clients = 16;
    spec.n_per_round = 3;
    spec.dataset_scale = 0.15;
    spec.seeds = vec![0, 1];
    spec.strategies = vec![StrategyKind::FedZero, StrategyKind::Random];
    spec
}

/// The acceptance criterion: for a fixed spec+seed the campaign report
/// is BYTE-identical at worker counts 1, 2 and 8 — scheduling, work
/// stealing and memoization races must be unobservable in the output.
#[test]
fn report_is_byte_identical_across_worker_counts() {
    let spec = small_spec();
    let reference = run_campaign(&spec, 1).unwrap();
    let ref_text = reference.report_json().to_string_pretty();
    assert_eq!(reference.results.len(), 4);
    for workers in [2usize, 8] {
        let run = run_campaign(&spec, workers).unwrap();
        let text = run.report_json().to_string_pretty();
        assert_eq!(
            text, ref_text,
            "report diverged at {workers} workers (len {} vs {})",
            text.len(),
            ref_text.len()
        );
    }
}

#[test]
fn memoization_shares_environments_across_strategies() {
    let spec = small_spec(); // 2 seeds × 2 strategies = 4 cells, 2 envs
    let run = run_campaign(&spec, 1).unwrap();
    assert_eq!(run.memo_misses, 2, "one build per seed expected");
    assert_eq!(run.memo_hits, 2, "strategy cells should share builds");
    assert!(run.memo_hit_rate() > 0.49);
}

#[test]
fn churn_axis_degrades_useful_energy() {
    // same env with and without heavy churn: the churned cells must see
    // outages reflected somewhere — fewer rounds, less energy, or more
    // waste — and never crash
    let mut spec = CampaignSpec::smoke();
    spec.name = "churn-axis".into();
    spec.strategies = vec![StrategyKind::Random];
    spec.churn_axis = vec![
        None,
        Some(ChurnSpec { outages_per_day: 40.0, mean_outage_min: 180.0 }),
    ];
    let run = run_campaign(&spec, 2).unwrap();
    assert_eq!(run.results.len(), 2);
    let calm = &run.results[0];
    let churned = &run.results[1];
    assert!(calm.rounds > 0 && churned.rounds > 0);
    // heavy churn (~5h offline per client-day) must not yield MORE
    // useful energy throughput than the calm world
    let calm_useful = calm.energy_kwh - calm.wasted_kwh;
    let churned_useful = churned.energy_kwh - churned.wasted_kwh;
    assert!(
        churned_useful <= calm_useful + 1e-9,
        "churned useful {churned_useful} > calm useful {calm_useful}"
    );
}

#[test]
fn custom_sites_battery_and_alpha_axes_run_end_to_end() {
    let mut spec = CampaignSpec::smoke();
    spec.name = "axes".into();
    spec.n_clients = 12;
    spec.n_per_round = 3;
    spec.dataset_scale = 0.15;
    spec.envs = vec![(
        "islands".into(),
        EnvSpec {
            sites: SiteSet::Custom(vec![
                Site::new("north", 55.0, 0.0, 0.2),
                Site::new("south", -30.0, 11.0, 0.2),
            ]),
            ..EnvSpec::global()
        },
    )];
    spec.alphas = vec![0.1, 1.0];
    spec.battery_axis = vec![0.0, 400.0];
    spec.strategies = vec![StrategyKind::FedZero];
    let run = run_campaign(&spec, 2).unwrap();
    assert_eq!(run.results.len(), 4);
    for r in &run.results {
        assert!(r.rounds > 0, "{} did no rounds", r.cell.label);
        assert!(r.fairness_jain > 0.0);
    }
    // the report round-trips through the JSON parser with every cell
    let parsed = Json::parse(&run.report_json().to_string_pretty()).unwrap();
    assert_eq!(parsed.get("n_cells").unwrap().as_usize(), Some(4));
    let cells = parsed.get("cells").unwrap().as_arr().unwrap();
    assert_eq!(cells.len(), 4);
    for c in cells {
        assert!(c.get("strategy").is_some());
        assert!(c.get("wasted_kwh").unwrap().as_f64().unwrap() >= 0.0);
        assert_eq!(c.get("env").unwrap().as_str(), Some("islands"));
    }
}
