//! Ring-arena equivalence at the full selection level: N consecutive
//! ring-advanced windows must drive FedZero to byte-identical
//! `SelectionDecision`s as fresh-built windows at the same forecast
//! anchor — across forecast-error models, dark periods, and blocklist
//! patterns. (The row-level byte identity is property-tested inside
//! `selection::ring`; this exercises the whole arena → probe → solver
//! pipeline on top.)

use fedzero::client::{ClientInfo, ClientProfile, DeviceType, ModelKind};
use fedzero::energy::PowerDomain;
use fedzero::selection::fedzero::{FedZero, SolverKind};
use fedzero::selection::incr::IncrSelState;
use fedzero::selection::ring::{FcBuffers, ForecastRing, SeriesSource};
use fedzero::selection::{ClientRoundState, SelectionContext, Strategy};
use fedzero::trace::forecast::SeriesForecaster;
use fedzero::util::prop::forall;
use fedzero::util::rng::Rng;

struct Scenario {
    clients: Vec<ClientInfo>,
    states: Vec<ClientRoundState>,
    domains: Vec<PowerDomain>,
    spare_now: Vec<f64>,
    src: SeriesSource,
    d_max: usize,
}

/// Random scenario with sine-shaped power (dark stretches included);
/// `realistic` toggles the horizon-growing forecast error, `dark` forces
/// an all-zero energy horizon.
fn random_scenario(rng: &mut Rng, realistic: bool, dark: bool) -> Scenario {
    let n_domains = rng.range(1, 4);
    let n_clients = rng.range(4, 16);
    let d_max = rng.range(5, 30);
    let horizon = d_max + 80;
    let clients: Vec<ClientInfo> = (0..n_clients)
        .map(|i| {
            let p = ClientProfile::new(
                DeviceType::ALL[rng.below(3)],
                ModelKind::Vision,
                10,
                1.0,
            );
            ClientInfo::new(i, rng.below(n_domains), p, (0..50).collect(), 10)
        })
        .collect();
    let mut states = vec![ClientRoundState::default(); n_clients];
    for s in states.iter_mut() {
        s.blocked = rng.bool(0.2);
        s.sigma = if s.blocked { 0.0 } else { rng.range_f64(0.0, 10.0) };
    }
    let power_series: Vec<Vec<f64>> = (0..n_domains)
        .map(|_| {
            if dark {
                vec![0.0; horizon]
            } else {
                let base = rng.range_f64(50.0, 800.0);
                (0..horizon)
                    .map(|t| (base * ((t as f64 / 15.0).sin())).max(0.0))
                    .collect()
            }
        })
        .collect();
    let domains: Vec<PowerDomain> = power_series
        .iter()
        .enumerate()
        .map(|(i, series)| {
            PowerDomain::new(
                i,
                "d",
                800.0,
                series.clone(),
                SeriesForecaster::perfect(series.clone()),
                1.0,
            )
        })
        .collect();
    let mk = |rng: &mut Rng, series: Vec<f64>| {
        if realistic {
            SeriesForecaster::realistic(series, rng.next_u64(), 60.0)
        } else {
            SeriesForecaster::perfect(series)
        }
    };
    // the source converts power (W) forecasts to Wh/step itself via the
    // domain; here we feed Wh/step series directly (step = 1 min)
    let energy_fc = power_series
        .iter()
        .map(|s| mk(rng, s.iter().map(|w| w / 60.0).collect()))
        .collect();
    let caps: Vec<f64> = clients.iter().map(|c| c.capacity()).collect();
    let spare_fc = caps
        .iter()
        .map(|&cap| {
            let series: Vec<f64> = (0..horizon)
                .map(|_| cap * rng.range_f64(0.2, 1.2))
                .collect();
            mk(rng, series)
        })
        .collect();
    let spare_now = caps.iter().map(|&c| c * 0.8).collect();
    Scenario {
        clients,
        states,
        domains,
        spare_now,
        src: SeriesSource { energy: energy_fc, spare: spare_fc, caps },
        d_max,
    }
}

fn select_with<'a>(
    s: &'a Scenario,
    fc: fedzero::selection::ring::FcView<'a>,
    incr: Option<&'a IncrSelState>,
    now: usize,
    n: usize,
    fz: &mut FedZero,
) -> fedzero::selection::SelectionDecision {
    let ctx = SelectionContext {
        now,
        n,
        d_max: s.d_max,
        clients: &s.clients,
        states: &s.states,
        domains: &s.domains,
        fc,
        incr,
        spare_now: &s.spare_now,
    };
    let mut rng = Rng::new(42);
    fz.select(&ctx, &mut rng)
}

fn check_scenario(rng: &mut Rng, realistic: bool, dark: bool) {
    let s = random_scenario(rng, realistic, dark);
    let n = rng.range(1, 5);
    let steps = rng.range(5, 25);
    let mut ring = ForecastRing::new();
    ring.rebuild(&s.src, 0, s.d_max);
    let mut incr = IncrSelState::new();
    incr.rebuild(&s.clients, &s.states, ring.view());
    for step in 0..steps {
        if step > 0 {
            incr.advance(&mut ring, &s.src);
        }
        let fresh = FcBuffers::from_source(&s.src, 0, step, s.d_max);
        let mut fz_ring = FedZero::new(SolverKind::Greedy);
        let mut fz_incr = FedZero::new(SolverKind::Greedy);
        let mut fz_fresh = FedZero::new(SolverKind::Greedy);
        let d_ring = select_with(&s, ring.view(), None, step, n, &mut fz_ring);
        let d_incr = select_with(&s, ring.view(), Some(&incr), step, n, &mut fz_incr);
        let d_fresh = select_with(&s, fresh.view(), None, step, n, &mut fz_fresh);
        assert_eq!(
            d_ring, d_fresh,
            "decision diverged at step {step} (realistic={realistic} dark={dark})"
        );
        assert_eq!(
            d_incr, d_fresh,
            "incremental-state decision diverged at step {step} \
             (realistic={realistic} dark={dark})"
        );
        if dark {
            assert!(d_ring.wait, "selected a round with zero energy");
        }
    }
}

#[test]
fn ring_selections_match_fresh_builds_perfect_forecasts() {
    forall(15, |rng| check_scenario(rng, false, false));
}

#[test]
fn ring_selections_match_fresh_builds_with_forecast_error() {
    forall(15, |rng| check_scenario(rng, true, false));
}

#[test]
fn ring_selections_match_fresh_builds_in_dark_periods() {
    forall(10, |rng| check_scenario(rng, true, true));
}

#[test]
fn exact_solver_agrees_over_ring_and_fresh_windows() {
    // the branch-and-bound path (with the per-domain energy-capacity
    // bound) must also be insensitive to the window backing
    forall(8, |rng| {
        let s = random_scenario(rng, true, false);
        let n = rng.range(1, 4);
        let mut ring = ForecastRing::new();
        ring.rebuild(&s.src, 0, s.d_max);
        let mut incr = IncrSelState::new();
        incr.rebuild(&s.clients, &s.states, ring.view());
        for step in 0..6 {
            if step > 0 {
                incr.advance(&mut ring, &s.src);
            }
            let fresh = FcBuffers::from_source(&s.src, 0, step, s.d_max);
            let mut fz_ring = FedZero::new(SolverKind::Exact);
            let mut fz_incr = FedZero::new(SolverKind::Exact);
            let mut fz_fresh = FedZero::new(SolverKind::Exact);
            let d_ring = select_with(&s, ring.view(), None, step, n, &mut fz_ring);
            let d_incr = select_with(&s, ring.view(), Some(&incr), step, n, &mut fz_incr);
            let d_fresh = select_with(&s, fresh.view(), None, step, n, &mut fz_fresh);
            assert_eq!(d_ring, d_fresh, "exact-solver divergence at {step}");
            assert_eq!(d_incr, d_fresh, "exact-solver incr divergence at {step}");
        }
    });
}
