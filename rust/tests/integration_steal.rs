//! Work-stealing scheduler integration gates (ISSUE 8): every fan-out
//! adopted by `util::par::steal` must produce bit-identical output at
//! 1, 2 and 8 workers on *adversarially skewed* inputs — the shapes
//! where stealing changes the schedule the most.
//!
//! Layer-local skew gates live next to their subjects (`solver::mip`:
//! one deep subtree; `fl::tree`: one giant domain; `fl::mock`: one
//! monster train job). This file covers the cross-layer paths: a full
//! campaign with one monster cell, and a full simulation run driven
//! through every stolen stage at once.

use fedzero::config::Scenario;
use fedzero::coordinator::{run_experiment, ExperimentSpec, StrategyKind};
use fedzero::scenario::campaign::{run_campaign, CampaignSpec};
use fedzero::sim::ChaosSpec;

/// One monster cell (exact solver × chaos: 16× the cost of the Random
/// baseline cells) among cheap ones — the static longest-first order
/// seeds it first, and stealing drains the cheap tail around it. The
/// report must stay byte-identical at 1, 2 and 8 workers.
#[test]
fn monster_cell_campaign_report_is_byte_identical_across_worker_counts() {
    let mut spec = CampaignSpec::smoke();
    spec.name = "monster-cell-fixture".into();
    spec.n_clients = 14;
    spec.n_per_round = 3;
    spec.dataset_scale = 0.15;
    spec.strategies = vec![StrategyKind::FedZeroExact, StrategyKind::Random];
    spec.chaos_axis = vec![
        None,
        Some(ChaosSpec { dropout_per_round: 0.2, ..ChaosSpec::default() }),
    ];
    let reference = run_campaign(&spec, 1).unwrap();
    let ref_text = reference.report_json().to_string_pretty();
    assert_eq!(reference.results.len(), 4);
    for workers in [2usize, 8] {
        let run = run_campaign(&spec, workers).unwrap();
        let text = run.report_json().to_string_pretty();
        assert_eq!(
            text, ref_text,
            "monster-cell report diverged at {workers} workers"
        );
    }
}

/// End-to-end: a full simulation (selection → grant water-filling →
/// sharded training → tree aggregation, all stolen fan-outs engaged by
/// the auto thread count) is a pure function of its spec — two
/// identical runs produce bit-identical metrics, so none of the stolen
/// stages leaks schedule into the output.
#[test]
fn full_sim_is_reproducible_with_stolen_fanouts_engaged() {
    let run = || {
        let spec = ExperimentSpec {
            preset: "tiny".into(),
            scenario: Scenario::Global,
            strategy: StrategyKind::FedZero,
            days: 1,
            n_clients: 20,
            n_per_round: 4,
            d_max: 60,
            dataset_scale: 0.1,
            eval_every: 10,
            eval_subset: 200,
            seed: 3,
            use_mock: true,
            ..Default::default()
        };
        run_experiment(&spec).unwrap()
    };
    let a = run();
    let b = run();
    assert_eq!(a.metrics.rounds.len(), b.metrics.rounds.len());
    assert_eq!(a.steps_executed, b.steps_executed);
    for (ra, rb) in a.metrics.rounds.iter().zip(&b.metrics.rounds) {
        assert_eq!(ra.batches.to_bits(), rb.batches.to_bits());
        assert_eq!(ra.mean_loss.to_bits(), rb.mean_loss.to_bits());
        assert_eq!(ra.energy_wh.to_bits(), rb.energy_wh.to_bits());
        assert_eq!(ra.participants, rb.participants);
    }
    let acc_a: Vec<u64> = a.metrics.evals.iter().map(|e| e.accuracy.to_bits()).collect();
    let acc_b: Vec<u64> = b.metrics.evals.iter().map(|e| e.accuracy.to_bits()).collect();
    assert_eq!(acc_a, acc_b);
}
