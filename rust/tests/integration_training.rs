//! Integration: full simulated FL training through the PJRT backend —
//! the three layers composing (Pallas kernels inside the HLO, executed by
//! the Rust coordinator under energy constraints) — plus a mock-backed
//! serial-vs-sharded train-path parity run that needs no artifacts.

use fedzero::client::{ClientInfo, ClientProfile, DeviceType, ModelKind};
use fedzero::config::Scenario;
use fedzero::coordinator::{run_experiment, ExperimentSpec, StrategyKind};
use fedzero::energy::PowerDomain;
use fedzero::fl::MockBackend;
use fedzero::metrics::MetricsLog;
use fedzero::selection::fedzero::{FedZero, SolverKind};
use fedzero::sim::{SimConfig, Simulation};
use fedzero::trace::forecast::{ErrorLevel, SeriesForecaster};

fn base_spec() -> ExperimentSpec {
    ExperimentSpec {
        preset: "tiny".into(),
        scenario: Scenario::Global,
        strategy: StrategyKind::FedZero,
        days: 1,
        n_clients: 20,
        n_per_round: 4,
        d_max: 60,
        dataset_scale: 0.1,
        eval_every: 10,
        eval_subset: 200,
        seed: 3,
        ..Default::default()
    }
}

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/tiny_manifest.json").exists()
}

#[test]
fn fedzero_training_learns_above_chance() {
    if !artifacts_available() {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let report = run_experiment(&base_spec()).unwrap();
    assert!(report.metrics.rounds.len() > 10);
    // tiny preset: 8 classes -> chance 12.5%
    assert!(
        report.metrics.best_accuracy() > 0.25,
        "acc {} not above chance",
        report.metrics.best_accuracy()
    );
    assert!(report.steps_executed > 100);
    assert!(report.metrics.total_energy_kwh() > 0.0);
}

#[test]
fn deterministic_given_seed() {
    if !artifacts_available() {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let a = run_experiment(&base_spec()).unwrap();
    let b = run_experiment(&base_spec()).unwrap();
    assert_eq!(a.metrics.rounds.len(), b.metrics.rounds.len());
    assert_eq!(a.steps_executed, b.steps_executed);
    let acc_a: Vec<f64> = a.metrics.evals.iter().map(|e| e.accuracy).collect();
    let acc_b: Vec<f64> = b.metrics.evals.iter().map(|e| e.accuracy).collect();
    assert_eq!(acc_a, acc_b);
}

#[test]
fn energy_never_exceeds_generation() {
    if !artifacts_available() {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let report = run_experiment(&base_spec()).unwrap();
    // 10 domains x 800 W x 24 h is a loose upper bound on harvestable energy
    let bound_kwh = 10.0 * 800.0 * 24.0 / 1000.0;
    assert!(report.metrics.total_energy_kwh() < bound_kwh);
    // per-round energy must be positive when batches were computed
    for r in &report.metrics.rounds {
        if r.batches > 0.5 {
            assert!(r.energy_wh > 0.0, "round {} free-rode", r.round);
        }
        assert!(r.duration_steps <= 60);
    }
}

#[test]
fn upper_bound_beats_constrained_in_time() {
    if !artifacts_available() {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let fz = run_experiment(&base_spec()).unwrap();
    let ub = run_experiment(&ExperimentSpec {
        strategy: StrategyKind::UpperBound,
        ..base_spec()
    })
    .unwrap();
    // the unconstrained baseline must do at least as many rounds
    assert!(
        ub.metrics.rounds.len() >= fz.metrics.rounds.len(),
        "upper bound {} rounds < fedzero {}",
        ub.metrics.rounds.len(),
        fz.metrics.rounds.len()
    );
}

/// Run a mock-backed FedZero sim with the shard fan-out forced on/off.
/// Returns (metrics, final global model bits, total train steps).
fn mock_parity_run(par_train_min: usize) -> (MetricsLog, Vec<u32>, u64) {
    let n_clients = 18;
    let n_domains = 6;
    let horizon = 500;
    let clients: Vec<ClientInfo> = (0..n_clients)
        .map(|i| {
            let p = ClientProfile::new(
                DeviceType::ALL[i % 3],
                ModelKind::Vision,
                10,
                1.0,
            );
            ClientInfo::new(i, i % n_domains, p, (0..60).collect(), 10)
        })
        .collect();
    let domains: Vec<PowerDomain> = (0..n_domains)
        .map(|i| {
            // staggered sine power so rounds see contention and dark gaps
            let series: Vec<f64> = (0..horizon)
                .map(|t| (400.0 * ((t + i * 37) as f64 / 29.0).sin()).max(0.0))
                .collect();
            PowerDomain::new(
                i,
                "d",
                800.0,
                series.clone(),
                SeriesForecaster::realistic(series, i as u64, 60.0),
                1.0,
            )
        })
        .collect();
    let load: Vec<Vec<f64>> =
        (0..n_clients).map(|_| vec![0.2; horizon]).collect();
    let load_fc: Vec<SeriesForecaster> = clients
        .iter()
        .map(|c| SeriesForecaster::perfect(vec![c.capacity(); horizon]))
        .collect();
    let mut backend = MockBackend::new(n_clients, 32, 0.3, 11);
    backend.par_min_jobs = par_train_min;
    let mut fz = FedZero::new(SolverKind::Greedy);
    let cfg = SimConfig {
        horizon,
        n_per_round: 6,
        d_max: 40,
        eval_every: 3,
        seed: 5,
        step_minutes: 1.0,
    };
    let mut sim = Simulation::new(
        cfg,
        clients,
        domains,
        load,
        load_fc,
        ErrorLevel::Realistic,
        &backend,
        &mut fz,
    );
    sim.run().unwrap();
    let steps = sim.steps_executed();
    let bits: Vec<u32> = sim.final_global.iter().map(|x| x.to_bits()).collect();
    (std::mem::take(&mut sim.metrics), bits, steps)
}

#[test]
fn sharded_training_is_bit_identical_end_to_end() {
    // whole-sim parity: metrics log, final global model (bitwise) and
    // the deterministic step totals must not depend on the fan-out
    let (m_ser, g_ser, s_ser) = mock_parity_run(usize::MAX);
    let (m_par, g_par, s_par) = mock_parity_run(1);
    assert!(!m_ser.rounds.is_empty(), "fixture executed no rounds");
    assert_eq!(m_par, m_ser, "MetricsLog diverged");
    assert_eq!(g_par, g_ser, "final global model diverged");
    assert_eq!(s_par, s_ser, "train-step totals diverged");
    assert!(s_ser > 0);
}

#[test]
fn seq_preset_with_imbalanced_partition_runs() {
    if !std::path::Path::new("artifacts/seq_manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let spec = ExperimentSpec {
        preset: "seq".into(),
        dataset_scale: 0.05,
        ..base_spec()
    };
    let report = run_experiment(&spec).unwrap();
    assert!(!report.metrics.rounds.is_empty());
    assert!(report.metrics.best_accuracy() > 0.05); // 32 classes, chance ~3%
}
