//! Integration: full simulated FL training through the PJRT backend —
//! the three layers composing (Pallas kernels inside the HLO, executed by
//! the Rust coordinator under energy constraints).

use fedzero::config::Scenario;
use fedzero::coordinator::{run_experiment, ExperimentSpec, StrategyKind};

fn base_spec() -> ExperimentSpec {
    ExperimentSpec {
        preset: "tiny".into(),
        scenario: Scenario::Global,
        strategy: StrategyKind::FedZero,
        days: 1,
        n_clients: 20,
        n_per_round: 4,
        d_max: 60,
        dataset_scale: 0.1,
        eval_every: 10,
        eval_subset: 200,
        seed: 3,
        ..Default::default()
    }
}

fn artifacts_available() -> bool {
    std::path::Path::new("artifacts/tiny_manifest.json").exists()
}

#[test]
fn fedzero_training_learns_above_chance() {
    if !artifacts_available() {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let report = run_experiment(&base_spec()).unwrap();
    assert!(report.metrics.rounds.len() > 10);
    // tiny preset: 8 classes -> chance 12.5%
    assert!(
        report.metrics.best_accuracy() > 0.25,
        "acc {} not above chance",
        report.metrics.best_accuracy()
    );
    assert!(report.steps_executed > 100);
    assert!(report.metrics.total_energy_kwh() > 0.0);
}

#[test]
fn deterministic_given_seed() {
    if !artifacts_available() {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let a = run_experiment(&base_spec()).unwrap();
    let b = run_experiment(&base_spec()).unwrap();
    assert_eq!(a.metrics.rounds.len(), b.metrics.rounds.len());
    assert_eq!(a.steps_executed, b.steps_executed);
    let acc_a: Vec<f64> = a.metrics.evals.iter().map(|e| e.accuracy).collect();
    let acc_b: Vec<f64> = b.metrics.evals.iter().map(|e| e.accuracy).collect();
    assert_eq!(acc_a, acc_b);
}

#[test]
fn energy_never_exceeds_generation() {
    if !artifacts_available() {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let report = run_experiment(&base_spec()).unwrap();
    // 10 domains x 800 W x 24 h is a loose upper bound on harvestable energy
    let bound_kwh = 10.0 * 800.0 * 24.0 / 1000.0;
    assert!(report.metrics.total_energy_kwh() < bound_kwh);
    // per-round energy must be positive when batches were computed
    for r in &report.metrics.rounds {
        if r.batches > 0.5 {
            assert!(r.energy_wh > 0.0, "round {} free-rode", r.round);
        }
        assert!(r.duration_steps <= 60);
    }
}

#[test]
fn upper_bound_beats_constrained_in_time() {
    if !artifacts_available() {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let fz = run_experiment(&base_spec()).unwrap();
    let ub = run_experiment(&ExperimentSpec {
        strategy: StrategyKind::UpperBound,
        ..base_spec()
    })
    .unwrap();
    // the unconstrained baseline must do at least as many rounds
    assert!(
        ub.metrics.rounds.len() >= fz.metrics.rounds.len(),
        "upper bound {} rounds < fedzero {}",
        ub.metrics.rounds.len(),
        fz.metrics.rounds.len()
    );
}

#[test]
fn seq_preset_with_imbalanced_partition_runs() {
    if !std::path::Path::new("artifacts/seq_manifest.json").exists() {
        eprintln!("SKIP: run `make artifacts`");
        return;
    }
    let spec = ExperimentSpec {
        preset: "seq".into(),
        dataset_scale: 0.05,
        ..base_spec()
    };
    let report = run_experiment(&spec).unwrap();
    assert!(!report.metrics.rounds.is_empty());
    assert!(report.metrics.best_accuracy() > 0.05); // 32 classes, chance ~3%
}
