//! Fault-injection integration tests: the ISSUE-6 determinism gate for
//! campaigns carrying a chaos axis (byte-identical reports across
//! worker counts — fault draws are pure functions of (seed, client,
//! round start), never of scheduling), the new robustness report
//! columns, and the churn-aware over-selection strategies end to end.

use fedzero::coordinator::StrategyKind;
use fedzero::scenario::campaign::{run_campaign, CampaignSpec};
use fedzero::scenario::ChurnSpec;
use fedzero::sim::ChaosSpec;
use fedzero::util::json::Json;

/// A 4-cell fixture: calm and faulty twins of the smoke env × 2 seeds.
fn chaos_spec() -> CampaignSpec {
    let mut spec = CampaignSpec::smoke();
    spec.name = "chaos-fixture".into();
    spec.n_clients = 16;
    spec.n_per_round = 3;
    spec.dataset_scale = 0.15;
    spec.seeds = vec![0, 1];
    spec.strategies = vec![StrategyKind::FedZero];
    spec.chaos_axis = vec![
        None,
        Some(ChaosSpec {
            dropout_per_round: 0.3,
            stale_prob: 0.3,
            ..ChaosSpec::default()
        }),
    ];
    spec
}

/// The acceptance criterion: seeded fault injection keeps the campaign
/// report BYTE-identical at worker counts 1, 2 and 8.
#[test]
fn chaos_report_is_byte_identical_across_worker_counts() {
    let spec = chaos_spec();
    let reference = run_campaign(&spec, 1).unwrap();
    let ref_text = reference.report_json().to_string_pretty();
    assert_eq!(reference.results.len(), 4);
    for workers in [2usize, 8] {
        let run = run_campaign(&spec, workers).unwrap();
        let text = run.report_json().to_string_pretty();
        assert_eq!(
            text, ref_text,
            "chaos report diverged at {workers} workers (len {} vs {})",
            text.len(),
            ref_text.len()
        );
    }
}

#[test]
fn chaos_cells_carry_fault_columns_and_share_builds() {
    let spec = chaos_spec(); // 2 chaos × 2 seeds, 1 strategy
    let run = run_campaign(&spec, 2).unwrap();
    // chaos is a sim-time knob: the calm and faulty twins of a seed
    // share one memoised environment build
    assert_eq!(run.memo_misses, 2, "one build per seed expected");
    assert_eq!(run.memo_hits, 2, "chaos twins should share builds");
    let parsed = Json::parse(&run.report_json().to_string_pretty()).unwrap();
    let cells = parsed.get("cells").unwrap().as_arr().unwrap();
    assert_eq!(cells.len(), 4);
    let mut faulty = 0usize;
    for c in cells {
        // the robustness columns are present on EVERY cell
        let rejected = c.get("rejected_updates").unwrap().as_usize().unwrap();
        let timeouts = c.get("timeout_rounds").unwrap().as_usize().unwrap();
        let chaos = c.get("chaos").unwrap().as_bool().unwrap();
        let label = c.get("label").unwrap().as_str().unwrap();
        assert!(
            label.contains(if chaos { "chaos1" } else { "chaos0" }),
            "label {label:?} does not mark chaos={chaos}"
        );
        let rounds = c.get("rounds").unwrap().as_usize().unwrap();
        assert!(timeouts <= rounds, "{label:?}: more timeouts than rounds");
        if chaos {
            faulty += 1;
        } else {
            // without injected faults there are no delayed submissions,
            // so nothing can ever be fenced as stale (rounds may still
            // time out honestly — a straggler under forecast error)
            assert_eq!(rejected, 0, "calm cell {label:?} rejected updates");
        }
        assert!(rounds > 0, "{label:?} did no rounds");
    }
    assert_eq!(faulty, 2);
}

#[test]
fn churn_aware_strategies_survive_heavy_churn_campaigns() {
    // the reactive over-selectors must run end to end under the same
    // heavy churn that motivates them, and report sane cells
    let mut spec = CampaignSpec::smoke();
    spec.name = "churn-aware".into();
    spec.strategies = vec![StrategyKind::FedZeroCa, StrategyKind::SemiSyncCa];
    spec.churn_axis = vec![Some(ChurnSpec {
        outages_per_day: 30.0,
        mean_outage_min: 120.0,
    })];
    let run = run_campaign(&spec, 2).unwrap();
    assert_eq!(run.results.len(), 2);
    for r in &run.results {
        assert!(r.rounds > 0, "{} did no rounds", r.cell.label);
        assert!(r.energy_kwh >= 0.0 && r.wasted_kwh >= 0.0);
    }
    // and the report stays deterministic with the wrappers in the loop
    let a = run.report_json().to_string_pretty();
    let b = run_campaign(&spec, 1).unwrap().report_json().to_string_pretty();
    assert_eq!(a, b, "churn-aware report diverged across worker counts");
}
