//! Integration-level property tests of the selection stack on randomized
//! scenarios (mock backend — no artifacts needed): the invariants the
//! paper's MIP formulation guarantees must survive the full pipeline.

use fedzero::client::{ClientInfo, ClientProfile, DeviceType, ModelKind};
use fedzero::energy::PowerDomain;
use fedzero::selection::baselines::Baseline;
use fedzero::selection::fedzero::{FedZero, SolverKind};
use fedzero::selection::ring::FcBuffers;
use fedzero::selection::{ClientRoundState, SelectionContext, Strategy};
use fedzero::trace::forecast::SeriesForecaster;
use fedzero::util::prop::forall;
use fedzero::util::rng::Rng;

struct Scenario {
    clients: Vec<ClientInfo>,
    states: Vec<ClientRoundState>,
    domains: Vec<PowerDomain>,
    fc: FcBuffers,
    spare_now: Vec<f64>,
}

fn random_scenario(rng: &mut Rng) -> Scenario {
    let n_domains = rng.range(1, 5);
    let n_clients = rng.range(4, 25);
    let horizon = 90usize;
    let d_max = 60usize;
    let clients: Vec<ClientInfo> = (0..n_clients)
        .map(|i| {
            let profile = ClientProfile::new(
                DeviceType::ALL[rng.below(3)],
                ModelKind::Vision,
                10,
                1.0,
            );
            let shard = rng.range(10, 120);
            ClientInfo::new(i, rng.below(n_domains), profile, (0..shard).collect(), 10)
        })
        .collect();
    let domains: Vec<PowerDomain> = (0..n_domains)
        .map(|i| {
            let base = rng.range_f64(0.0, 800.0);
            let series: Vec<f64> = (0..horizon)
                .map(|t| {
                    (base * (0.5 + 0.5 * ((t as f64 / 20.0).sin()))).max(0.0)
                })
                .collect();
            PowerDomain::new(
                i,
                "d",
                800.0,
                series.clone(),
                SeriesForecaster::perfect(series),
                1.0,
            )
        })
        .collect();
    let mut states = vec![ClientRoundState::default(); n_clients];
    for s in states.iter_mut() {
        s.participation = rng.below(6);
        s.sigma = rng.range_f64(0.0, 20.0);
        s.blocked = rng.bool(0.2);
        if s.blocked {
            s.sigma = 0.0;
        }
    }
    let energy_fc: Vec<Vec<f64>> = domains
        .iter()
        .map(|d| d.forecast_window_wh(0, d_max))
        .collect();
    let spare_fc: Vec<Vec<f64>> = clients
        .iter()
        .map(|c| {
            let cap = c.capacity();
            (0..d_max).map(|_| cap * rng.range_f64(0.2, 1.0)).collect()
        })
        .collect();
    let fc = FcBuffers::from_rows(&energy_fc, &spare_fc, d_max);
    let spare_now = clients.iter().map(|c| c.capacity() * 0.8).collect();
    Scenario { clients, states, domains, fc, spare_now }
}

fn ctx<'a>(s: &'a Scenario, n: usize) -> SelectionContext<'a> {
    SelectionContext {
        now: 0,
        n,
        d_max: 60,
        clients: &s.clients,
        states: &s.states,
        domains: &s.domains,
        fc: s.fc.view(),
        incr: None,
        spare_now: &s.spare_now,
    }
}

#[test]
fn fedzero_selection_invariants() {
    forall(60, |rng| {
        let s = random_scenario(rng);
        let n = rng.range(1, 8);
        let mut fz = FedZero::new(SolverKind::Greedy);
        let mut srng = Rng::new(42);
        let d = fz.select(&ctx(&s, n), &mut srng);
        if d.wait {
            return;
        }
        // exactly n distinct clients
        assert_eq!(d.clients.len(), n, "selected {} != n {n}", d.clients.len());
        let mut u = d.clients.clone();
        u.sort_unstable();
        u.dedup();
        assert_eq!(u.len(), n, "duplicate selections");
        // never blocked / zero-sigma clients
        for &c in &d.clients {
            assert!(!s.states[c].blocked, "blocked client {c} selected");
            assert!(s.states[c].sigma > 0.0);
        }
        // every selected client passes the reachability filter at d
        let c0 = ctx(&s, n);
        for &c in &d.clients {
            assert!(
                c0.reachable_min(c, d.expected_duration),
                "client {c} cannot reach m_min within d={}",
                d.expected_duration
            );
        }
        assert!(d.expected_duration >= 1 && d.expected_duration <= 60);
    });
}

#[test]
fn fedzero_duration_is_minimal_among_feasible() {
    // the binary search must return a d such that d-1 has no full
    // solution (checked via a fresh search constrained to d-1)
    forall(30, |rng| {
        let s = random_scenario(rng);
        let n = rng.range(1, 5);
        let mut fz = FedZero::new(SolverKind::Greedy);
        let mut srng = Rng::new(7);
        let d = fz.select(&ctx(&s, n), &mut srng);
        if d.wait || d.expected_duration == 1 {
            return;
        }
        // instance at d-1 must be missing candidates or unsolvable
        let c1 = ctx(&s, n);
        let arena = fedzero::selection::arena::SelArena::build(&c1);
        let mut scratch = fedzero::selection::arena::ProbeScratch::new();
        if arena.fill_probe(&mut scratch, d.expected_duration - 1) {
            let mut ws = fedzero::solver::alloc::AllocWorkspace::default();
            let sol =
                fedzero::solver::mip::greedy_view(scratch.instance(), 1, &mut ws);
            // greedy is not exact, so we only assert it did not find MORE
            // than n (structural sanity), and usually finds < n.
            assert!(sol.chosen.len() <= n);
        }
    });
}

#[test]
fn baselines_select_only_available_clients() {
    forall(60, |rng| {
        let s = random_scenario(rng);
        let n = rng.range(1, 6);
        for mut b in [
            Baseline::random(),
            Baseline::random_over(),
            Baseline::random_fc(),
            Baseline::oort(),
            Baseline::oort_over(),
            Baseline::oort_fc(),
        ] {
            let mut srng = Rng::new(11);
            let d = b.select(&ctx(&s, n), &mut srng);
            if d.wait {
                continue;
            }
            assert!(d.clients.len() >= n, "{}", b.name());
            let avail = ctx(&s, n).available_now();
            for &c in &d.clients {
                assert!(
                    avail.contains(&c),
                    "{} selected unavailable client {c}",
                    b.name()
                );
            }
            assert_eq!(d.n_required, n.min(d.clients.len()));
        }
    });
}

#[test]
fn blocklist_cycle_releases_under_participants() {
    forall(40, |rng| {
        let s = random_scenario(rng);
        let mut states = s.states.clone();
        let mut fz = FedZero::new(SolverKind::Greedy);
        let participants: Vec<usize> =
            (0..states.len()).filter(|_| rng.bool(0.3)).collect();
        let mut srng = Rng::new(13);
        fz.on_round_end(&participants, &mut states, &mut srng);
        // release probability is 1 for anyone at or below mean
        // participation (p − ω ≤ 1 ⇒ P(release) = 1), so they must all be
        // unblocked after the cycle — participants included.
        let mean = states.iter().map(|st| st.participation as f64).sum::<f64>()
            / states.len() as f64;
        for (i, st) in states.iter().enumerate() {
            if (st.participation as f64) <= mean {
                assert!(!st.blocked, "under-participant {i} stayed blocked");
            }
        }
    });
}
