//! Campaign-runner benches + CI gates.
//!
//! Measures cells/second for a serial vs parallel drain of a campaign
//! grid (and the trace-memoization hit rate that makes the parallel
//! drain worthwhile), and gates three correctness properties:
//!
//! 1. **schema** — the campaign report parses and carries the required
//!    keys for every cell;
//! 2. **determinism** — the report is byte-identical at 1 vs 2 workers;
//! 3. **legacy equivalence** — the builtin global spec routed through
//!    the declarative scenario engine reproduces the legacy
//!    `config::build` path's `MetricsLog` exactly.
//!
//! Any gate failure exits non-zero (wired into ci.sh like the ring and
//! train divergence gates). Results go to rust/BENCH_campaign.json.
//!
//! Flags: --quick  CI smoke (2-cell campaign)

use std::collections::BTreeMap;

use fedzero::client::ModelKind;
use fedzero::config::{build, Scenario, ScenarioConfig};
use fedzero::coordinator::{build_dataset, run_built_mock, run_experiment, ExperimentSpec, StrategyKind};
use fedzero::scenario::campaign::{run_campaign, CampaignSpec};
use fedzero::scenario::EnvSpec;
use fedzero::util::json::Json;
use fedzero::util::obs;
use fedzero::util::par;

/// The bench grid: the 2-cell smoke campaign in quick mode, a 16-cell
/// two-scenario sweep otherwise.
fn bench_spec(quick: bool) -> CampaignSpec {
    let mut spec = CampaignSpec::smoke();
    if !quick {
        spec.name = "bench-grid".into();
        spec.envs = vec![
            ("global".into(), EnvSpec::global()),
            ("colocated".into(), EnvSpec::colocated()),
        ];
        spec.alphas = vec![0.1, 0.5];
        spec.seeds = vec![0, 1];
        spec.strategies = vec![StrategyKind::FedZero, StrategyKind::Random];
    }
    spec
}

/// Gate 1: required report keys, cell count, parseability.
fn validate_schema(report: &Json, expect_cells: usize) -> Result<(), String> {
    let text = report.to_string_pretty();
    let parsed = Json::parse(&text).map_err(|e| format!("report does not re-parse: {e}"))?;
    for key in ["campaign", "preset", "days", "clients", "target_accuracy", "n_cells", "cells"] {
        if parsed.get(key).is_none() {
            return Err(format!("report missing key {key:?}"));
        }
    }
    if parsed.get("n_cells").and_then(|v| v.as_usize()) != Some(expect_cells) {
        return Err("n_cells mismatch".into());
    }
    let cells = parsed
        .get("cells")
        .and_then(|v| v.as_arr())
        .ok_or("cells is not an array")?;
    if cells.len() != expect_cells {
        return Err(format!("expected {expect_cells} cells, got {}", cells.len()));
    }
    for (i, cell) in cells.iter().enumerate() {
        for key in [
            "cell", "label", "env", "alpha", "energy_error", "load_error", "battery_wh",
            "churn", "chaos", "seed", "strategy", "rounds", "best_accuracy",
            "time_to_target_days", "energy_to_target_kwh", "energy_kwh", "wasted_kwh",
            "mean_round_min", "fairness_domain_std", "fairness_jain", "train_steps",
            "rejected_updates", "timeout_rounds",
        ] {
            if cell.get(key).is_none() {
                return Err(format!("cell {i} missing key {key:?}"));
            }
        }
        if cell.get("cell").and_then(|v| v.as_usize()) != Some(i) {
            return Err(format!("cell {i} has wrong index"));
        }
    }
    Ok(())
}

/// Gate 3: the declarative builtin-global path vs the legacy
/// enum-driven `config::build` path, `MetricsLog`-equal.
fn legacy_divergence() -> usize {
    let mut mismatches = 0;
    for seed in [0u64, 11] {
        let spec = ExperimentSpec {
            use_mock: true,
            days: 1,
            n_clients: 20,
            n_per_round: 4,
            d_max: 30,
            scenario: Scenario::Global,
            preset: "tiny".into(),
            dataset_scale: 0.2,
            seed,
            ..Default::default()
        };
        let fresh = match run_experiment(&spec) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("spec-driven run failed (seed {seed}): {e:#}");
                mismatches += 1;
                continue;
            }
        };
        let (_, partition) = build_dataset(&spec, 16);
        let legacy_built = build(
            &ScenarioConfig {
                scenario: Scenario::Global,
                n_clients: spec.n_clients,
                days: spec.days,
                step_minutes: 1.0,
                domain_capacity_w: 800.0,
                energy_error: spec.energy_error,
                load_error: spec.load_error,
                unlimited_domain: None,
                seed,
            },
            ModelKind::from_preset(&spec.preset),
            10,
            &partition,
        );
        let legacy = match run_built_mock(&spec, legacy_built) {
            Ok(r) => r,
            Err(e) => {
                eprintln!("legacy run failed (seed {seed}): {e:#}");
                mismatches += 1;
                continue;
            }
        };
        if fresh.metrics != legacy.metrics || fresh.steps_executed != legacy.steps_executed {
            eprintln!(
                "LEGACY DIVERGENCE (seed {seed}): spec-driven builtin != config::build \
                 ({} vs {} rounds, {} vs {} steps)",
                fresh.metrics.rounds.len(),
                legacy.metrics.rounds.len(),
                fresh.steps_executed,
                legacy.steps_executed,
            );
            mismatches += 1;
        }
    }
    mismatches
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mode = if quick { "quick" } else { "default" };
    println!("== campaign benches [{mode}] ==");
    // telemetry on for the whole bench: the determinism gate doubles as
    // proof the probes change no report byte, and the snapshot feeds the
    // per-cell wall-time percentile columns
    obs::set_enabled(true);
    obs::reset();

    let spec = bench_spec(quick);
    let n_cells = spec.expand().len();

    // --- serial vs parallel drain -----------------------------------------
    let serial = run_campaign(&spec, 1).expect("serial campaign failed");
    let cells_per_s_serial = n_cells as f64 / serial.wall_s.max(1e-9);
    let workers = par::threads().max(2);
    let parallel = run_campaign(&spec, workers).expect("parallel campaign failed");
    let cells_per_s_parallel = n_cells as f64 / parallel.wall_s.max(1e-9);
    println!(
        "campaign/{n_cells}cells serial   {:>8.2} cells/s ({:.2}s)",
        cells_per_s_serial, serial.wall_s
    );
    println!(
        "campaign/{n_cells}cells x{workers:<2}      {:>8.2} cells/s ({:.2}s, speedup {:.2}x)",
        cells_per_s_parallel,
        parallel.wall_s,
        cells_per_s_parallel / cells_per_s_serial.max(1e-9)
    );
    println!(
        "trace memoization: serial {}/{} hits ({:.0}%), parallel {}/{} ({:.0}%)",
        serial.memo_hits,
        serial.memo_hits + serial.memo_misses,
        serial.memo_hit_rate() * 100.0,
        parallel.memo_hits,
        parallel.memo_hits + parallel.memo_misses,
        parallel.memo_hit_rate() * 100.0,
    );
    println!(
        "dataset memoization: serial {}/{} hits ({:.0}%), parallel {}/{} ({:.0}%)",
        serial.dataset_hits,
        serial.dataset_hits + serial.dataset_misses,
        serial.dataset_hit_rate() * 100.0,
        parallel.dataset_hits,
        parallel.dataset_hits + parallel.dataset_misses,
        parallel.dataset_hit_rate() * 100.0,
    );

    // --- gates -------------------------------------------------------------
    let report = serial.report_json();
    let schema_err = validate_schema(&report, n_cells).err();
    if let Some(e) = &schema_err {
        eprintln!("SCHEMA GATE FAILED: {e}");
    } else {
        println!("schema gate: ok ({n_cells} cells validated)");
    }

    let determinism_mismatch =
        (report.to_string_pretty() != parallel.report_json().to_string_pretty()) as usize;
    if determinism_mismatch > 0 {
        eprintln!("DETERMINISM GATE FAILED: serial vs {workers}-worker reports differ");
    } else {
        println!("determinism gate: ok (serial == {workers}-worker report, byte for byte)");
    }

    let legacy_mismatches = legacy_divergence();
    if legacy_mismatches > 0 {
        eprintln!("LEGACY GATE FAILED: {legacy_mismatches} mismatches");
    } else {
        println!("legacy-equivalence gate: ok (builtin spec == config::build path)");
    }

    // --- machine-readable results ------------------------------------------
    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("campaign".into()));
    root.insert("mode".into(), Json::Str(mode.into()));
    root.insert("cells".into(), Json::Num(n_cells as f64));
    root.insert("workers".into(), Json::Num(workers as f64));
    root.insert("cells_per_s_serial".into(), Json::Num(cells_per_s_serial));
    root.insert("cells_per_s_parallel".into(), Json::Num(cells_per_s_parallel));
    root.insert(
        "speedup".into(),
        Json::Num(cells_per_s_parallel / cells_per_s_serial.max(1e-9)),
    );
    root.insert("memo_hit_rate".into(), Json::Num(serial.memo_hit_rate()));
    root.insert(
        "dataset_memo_hit_rate".into(),
        Json::Num(serial.dataset_hit_rate()),
    );
    root.insert(
        "schema_failures".into(),
        Json::Num(schema_err.is_some() as usize as f64),
    );
    root.insert(
        "determinism_mismatch".into(),
        Json::Num(determinism_mismatch as f64),
    );
    root.insert("legacy_divergence".into(), Json::Num(legacy_mismatches as f64));
    // per-cell wall-time distribution over every drain above (the _ns
    // keys join the ratchet once a baseline is armed)
    let s = obs::snapshot();
    root.insert(
        "cell_wall_p50_ns".into(),
        Json::Num(s.hist_percentile(obs::Hist::CellWallNs, 50.0)),
    );
    root.insert(
        "cell_wall_p99_ns".into(),
        Json::Num(s.hist_percentile(obs::Hist::CellWallNs, 99.0)),
    );
    root.insert(
        "cell_wall_sparkline".into(),
        Json::Str(s.hist_sparkline(obs::Hist::CellWallNs)),
    );
    let out = Json::Obj(root).to_string_pretty();
    let path = "BENCH_campaign.json";
    match fedzero::util::fsx::write_atomic(std::path::Path::new(path), out.as_bytes()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    if schema_err.is_some() || determinism_mismatch > 0 || legacy_mismatches > 0 {
        eprintln!("campaign gates FAILED");
        std::process::exit(1);
    }
    println!("== done ==");
}
