//! Fig 8 reproduction bench: selection overhead vs scale.
//!
//! (a) runtime vs number of clients (with domains = clients/10)
//! (b) runtime vs number of domains at fixed clients
//! plus the paper's headline points: 100 clients/10 domains/60 steps
//! (paper: ~0.1 s with Gurobi) and 100k/100k/1440 (paper: < 2 min).
//! Pass --full to include the 100k-scale points.

use std::time::Instant;

use fedzero::solver::mip::{greedy, SelClient, SelInstance};
use fedzero::util::bench::{bench, fmt_ns, Config};
use fedzero::util::rng::Rng;

fn instance(c: usize, p: usize, t: usize, seed: u64) -> SelInstance {
    let mut rng = Rng::new(seed);
    SelInstance {
        n: 10,
        clients: (0..c)
            .map(|_| {
                let m_min = rng.range_f64(5.0, 40.0);
                SelClient {
                    domain: rng.below(p),
                    sigma: rng.range_f64(0.1, 10.0),
                    delta: rng.range_f64(0.05, 0.5),
                    m_min,
                    m_max: m_min * 5.0,
                    spare: (0..t).map(|_| rng.range_f64(0.0, 40.0)).collect(),
                }
            })
            .collect(),
        energy: (0..p)
            .map(|_| (0..t).map(|_| rng.range_f64(0.0, 14.0)).collect())
            .collect(),
    }
}

fn main() {
    let full = std::env::args().any(|a| a == "--full");
    println!("== selection scaling (Fig 8) ==");

    // (a) clients sweep — evaluation scale measured precisely
    let eval_scale = instance(100, 10, 60, 1);
    let r = bench("fig8a/100c_10p_60t", Config::default(), || {
        greedy(&eval_scale, 1)
    });
    println!(
        "   paper reports ~0.1 s at this scale (Gurobi); ours: {}",
        fmt_ns(r.median_ns())
    );

    for c in [1_000usize, 10_000] {
        let inst = instance(c, c / 10, 60, 2);
        let t0 = Instant::now();
        let _ = greedy(&inst, 1);
        println!(
            "fig8a/{c}c: single run {:.3} s",
            t0.elapsed().as_secs_f64()
        );
    }

    // (b) domains sweep at fixed clients
    for p in [10usize, 100, 1_000] {
        let inst = instance(10_000, p, 60, 3);
        let t0 = Instant::now();
        let _ = greedy(&inst, 1);
        println!(
            "fig8b/10kc_{p}p: single run {:.3} s",
            t0.elapsed().as_secs_f64()
        );
    }

    if full {
        for (c, p, t) in [(100_000usize, 10_000usize, 60usize), (100_000, 100_000, 1_440)] {
            let inst = instance(c, p, t, 4);
            let t0 = Instant::now();
            let _ = greedy(&inst, 1);
            println!(
                "fig8/{c}c_{p}p_{t}t: single run {:.2} s (paper envelope: 120 s)",
                t0.elapsed().as_secs_f64()
            );
        }
    } else {
        println!("(pass --full for the 100k-client paper-scale points)");
    }
    println!("== done ==");
}
