//! Fig 8 reproduction bench: selection overhead vs scale.
//!
//! (a) runtime vs number of clients (with domains = clients/10)
//! (b) runtime vs number of domains at fixed clients
//! plus the paper's headline points: 100 clients/10 domains/60 steps
//! (paper: ~0.1 s with Gurobi) and 100k/100k/1440 (paper: < 2 min).
//!
//! Every measured point also runs `reference_greedy` (the retained
//! pre-arena implementation) where affordable, asserts the two solvers
//! return identical `chosen` sets and objectives (within 1e-9), and the
//! whole run is written to BENCH_selection.json so the perf trajectory
//! is tracked across PRs (fields: median_ns / ref_median_ns /
//! speedup_vs_reference per point).
//!
//! A branch-and-bound point additionally records exact-solver node
//! throughput forced-serial vs forced-parallel (shared-incumbent subtree
//! fan-out) and fails the run if completed searches disagree — the
//! serial/parallel identity guarantee of `solver::mip`.
//!
//! A second B&B point runs a deliberately SKEWED tree (one contended
//! domain full of exact score ties → one frontier subtree dwarfs the
//! rest) under all three drains (`BnbDrain::Serial` / `Chunked` /
//! `Steal`), recording node throughput per drain plus the stealing
//! telemetry (steal count, stolen subtrees) that shows redistribution
//! actually happened. Completed searches must agree bitwise across
//! drains AND across 1/2/8 pinned workers — exit 1 on divergence.
//!
//! Flags: --quick  CI smoke (small points only, few samples)
//!        --full   add the 100k-scale paper-envelope points
//!        --steal  ONLY the skewed-tree drain comparison + its bitwise
//!                 gate (fast enough for `ci.sh --quick`; writes
//!                 BENCH_selection.json with mode "steal")

use std::collections::BTreeMap;
use std::hint::black_box;
use std::time::Instant;

use fedzero::solver::alloc::AllocWorkspace;
use fedzero::solver::mip::{
    branch_and_bound_view_drained, branch_and_bound_view_forced, greedy,
    reference_greedy, BnbDrain, SelClient, SelInstance, SelSolution,
};
use fedzero::util::json::Json;
use fedzero::util::rng::Rng;
use fedzero::util::stats;
use fedzero::util::bench::fmt_ns;

fn instance(c: usize, p: usize, t: usize, seed: u64) -> SelInstance {
    let mut rng = Rng::new(seed);
    SelInstance {
        n: 10,
        clients: (0..c)
            .map(|_| {
                let m_min = rng.range_f64(5.0, 40.0);
                SelClient {
                    domain: rng.below(p),
                    sigma: rng.range_f64(0.1, 10.0),
                    delta: rng.range_f64(0.05, 0.5),
                    m_min,
                    m_max: m_min * 5.0,
                    spare: (0..t)
                        .map(|_| rng.range_f64(0.0, 40.0) as f32)
                        .collect(),
                }
            })
            .collect(),
        energy: (0..p)
            .map(|_| {
                (0..t).map(|_| rng.range_f64(0.0, 14.0) as f32).collect()
            })
            .collect(),
    }
}

/// Median wall-clock ns of `runs` invocations of `f`.
fn time_runs<T, F: FnMut() -> T>(runs: usize, mut f: F) -> Vec<f64> {
    let mut ns = Vec::with_capacity(runs);
    for _ in 0..runs {
        let t0 = Instant::now();
        black_box(f());
        ns.push(t0.elapsed().as_nanos() as f64);
    }
    ns
}

struct Point {
    name: String,
    clients: usize,
    domains: usize,
    steps: usize,
    n_select: usize,
    samples_ns: Vec<f64>,
    ref_samples_ns: Option<Vec<f64>>,
    chosen_matches_reference: Option<bool>,
}

impl Point {
    fn median(&self) -> f64 {
        stats::percentile(&self.samples_ns, 50.0)
    }

    fn ref_median(&self) -> Option<f64> {
        self.ref_samples_ns
            .as_ref()
            .map(|s| stats::percentile(s, 50.0))
    }

    fn speedup(&self) -> Option<f64> {
        self.ref_median().map(|r| r / self.median())
    }

    fn report(&self) {
        match (self.ref_median(), self.speedup()) {
            (Some(r), Some(s)) => println!(
                "{:<24} median {:>12}  (reference {:>12}, speedup {:.1}x, chosen match: {})",
                self.name,
                fmt_ns(self.median()),
                fmt_ns(r),
                s,
                self.chosen_matches_reference
                    .map(|b| if b { "yes" } else { "NO" })
                    .unwrap_or("-"),
            ),
            _ => println!(
                "{:<24} median {:>12}  (reference not run at this scale)",
                self.name,
                fmt_ns(self.median()),
            ),
        }
    }

    fn to_json(&self) -> Json {
        let mut m = BTreeMap::new();
        m.insert("name".into(), Json::Str(self.name.clone()));
        m.insert("clients".into(), Json::Num(self.clients as f64));
        m.insert("domains".into(), Json::Num(self.domains as f64));
        m.insert("steps".into(), Json::Num(self.steps as f64));
        m.insert("n_select".into(), Json::Num(self.n_select as f64));
        m.insert(
            "samples".into(),
            Json::Num(self.samples_ns.len() as f64),
        );
        m.insert("median_ns".into(), Json::Num(self.median()));
        m.insert("mean_ns".into(), Json::Num(stats::mean(&self.samples_ns)));
        m.insert("p50_ns".into(), Json::Num(self.median()));
        m.insert(
            "p95_ns".into(),
            Json::Num(stats::percentile(&self.samples_ns, 95.0)),
        );
        m.insert(
            "p99_ns".into(),
            Json::Num(stats::percentile(&self.samples_ns, 99.0)),
        );
        m.insert(
            "ref_median_ns".into(),
            self.ref_median().map(Json::Num).unwrap_or(Json::Null),
        );
        m.insert(
            "speedup_vs_reference".into(),
            self.speedup().map(Json::Num).unwrap_or(Json::Null),
        );
        m.insert(
            "chosen_matches_reference".into(),
            self.chosen_matches_reference
                .map(Json::Bool)
                .unwrap_or(Json::Null),
        );
        Json::Obj(m)
    }
}

/// Equivalent = identical chosen set, or an exact tie (objective within
/// 1e-12 relative) that flipped on a last-ulp difference between the
/// singleton closed form and the flow solve. Anything beyond 1e-9
/// relative objective difference is a hard failure.
fn assert_equivalent(name: &str, fast: &SelSolution, slow: &SelSolution) -> bool {
    let chosen_ok = fast.chosen == slow.chosen;
    let obj_diff = (fast.objective - slow.objective).abs();
    let scale = 1.0 + slow.objective.abs();
    let tie_flip = !chosen_ok && obj_diff < 1e-12 * scale;
    if tie_flip {
        eprintln!(
            "note: {name}: chosen sets differ on an exact tie \
             (objective {} vs {}) — accepted",
            fast.objective, slow.objective
        );
    }
    let ok = (chosen_ok || tie_flip) && obj_diff < 1e-9 * scale;
    if !ok {
        eprintln!(
            "EQUIVALENCE FAILURE at {name}: chosen match={chosen_ok} \
             objective {} vs reference {}",
            fast.objective, slow.objective
        );
    }
    ok
}

/// Measure one point; `runs`/`ref_runs` control the sample count, and
/// `ref_runs == 0` skips the reference implementation (too slow at the
/// largest scales).
fn point(
    name: &str,
    c: usize,
    p: usize,
    t: usize,
    seed: u64,
    runs: usize,
    ref_runs: usize,
) -> Point {
    let inst = instance(c, p, t, seed);
    // warmup + solutions for the equivalence check
    let fast_sol = greedy(&inst, 1);
    let samples_ns = time_runs(runs, || greedy(&inst, 1));
    let (ref_samples_ns, chosen_matches_reference) = if ref_runs > 0 {
        let slow_sol = reference_greedy(&inst, 1);
        let ok = assert_equivalent(name, &fast_sol, &slow_sol);
        let ns = time_runs(ref_runs, || reference_greedy(&inst, 1));
        (Some(ns), Some(ok))
    } else {
        (None, None)
    };
    let pt = Point {
        name: name.to_string(),
        clients: c,
        domains: p,
        steps: t,
        n_select: inst.n,
        samples_ns,
        ref_samples_ns,
        chosen_matches_reference,
    };
    pt.report();
    pt
}

/// Branch-and-bound node throughput, forced-serial vs forced-parallel on
/// the same seeded instance. Returns (json, mismatch): results must be
/// identical whenever both searches complete (the canonical-reduction
/// guarantee; mismatch fails the bench like the greedy equivalence
/// checks).
fn bnb_point(budget: usize) -> (Json, bool) {
    let inst = instance(40, 5, 8, 77);
    let vs = inst.view_storage();
    let mut ws1 = AllocWorkspace::default();
    let mut ws2 = AllocWorkspace::default();
    let t0 = Instant::now();
    let (ser, nodes_ser) = branch_and_bound_view_forced(vs.view(), budget, &mut ws1, false);
    let dt_ser = t0.elapsed().as_secs_f64();
    let t1 = Instant::now();
    let (par, nodes_par) = branch_and_bound_view_forced(vs.view(), budget, &mut ws2, true);
    let dt_par = t1.elapsed().as_secs_f64();
    let nps_ser = nodes_ser as f64 / dt_ser.max(1e-9);
    let nps_par = nodes_par as f64 / dt_par.max(1e-9);
    let both_complete = ser.optimal && par.optimal;
    let mismatch = both_complete
        && (ser.chosen != par.chosen
            || ser.objective.to_bits() != par.objective.to_bits());
    println!(
        "bnb/40c_5p_8t serial {nodes_ser} nodes in {dt_ser:.3} s ({nps_ser:.0}/s), \
         parallel {nodes_par} nodes in {dt_par:.3} s ({nps_par:.0}/s, \
         wallclock speedup {:.2}x){}{}",
        dt_ser / dt_par.max(1e-9),
        if both_complete { "" } else { " [budget exhausted]" },
        if mismatch { " MISMATCH" } else { "" },
    );
    let mut m = BTreeMap::new();
    m.insert("clients".into(), Json::Num(40.0));
    m.insert("domains".into(), Json::Num(5.0));
    m.insert("steps".into(), Json::Num(8.0));
    m.insert("node_budget".into(), Json::Num(budget as f64));
    m.insert("nodes_serial".into(), Json::Num(nodes_ser as f64));
    m.insert("nodes_parallel".into(), Json::Num(nodes_par as f64));
    m.insert("nodes_per_s_serial".into(), Json::Num(nps_ser));
    m.insert("nodes_per_s_parallel".into(), Json::Num(nps_par));
    m.insert(
        "wallclock_speedup".into(),
        Json::Num(dt_ser / dt_par.max(1e-9)),
    );
    m.insert("complete_serial".into(), Json::Bool(ser.optimal));
    m.insert("complete_parallel".into(), Json::Bool(par.optimal));
    // null (not true) when the equivalence was never checkable — the
    // identity guarantee only covers completed searches, matching the
    // chosen_matches_reference convention of the greedy points
    m.insert(
        "chosen_match".into(),
        if both_complete { Json::Bool(!mismatch) } else { Json::Null },
    );
    (Json::Obj(m), mismatch)
}

/// Adversarially skewed B&B instance: a contended low-energy domain full
/// of exact score ties (identical sigma/delta, spare jittered only in
/// the last float bits) makes pruning ineffective inside ONE frontier
/// subtree, which then dwarfs every other subtree — the shape where a
/// uniform frontier split leaves most workers idle at the join and
/// stealing should win.
fn skewed_bnb_instance(seed: u64) -> SelInstance {
    let mut rng = Rng::new(seed);
    let t_n = 4usize;
    let mut clients = Vec::new();
    for i in 0..12 {
        let m_min = 1.0;
        clients.push(SelClient {
            domain: 0,
            sigma: 1.0,
            delta: 1.0,
            m_min,
            m_max: m_min + 4.0,
            spare: (0..t_n)
                .map(|t| (1.0 + ((i + t) % 3) as f64 * 1e-6) as f32)
                .collect(),
        });
    }
    for p in 1..4 {
        let m_min = rng.range_f64(0.5, 1.0);
        clients.push(SelClient {
            domain: p,
            sigma: rng.range_f64(0.5, 1.5),
            delta: 1.0,
            m_min,
            m_max: m_min + 3.0,
            spare: (0..t_n).map(|_| rng.range_f64(0.5, 1.5) as f32).collect(),
        });
    }
    let energy = (0..4)
        .map(|p| {
            let base = if p == 0 { 1.5 } else { 4.0 };
            (0..t_n).map(|_| base as f32).collect()
        })
        .collect();
    SelInstance { n: 4, clients, energy }
}

/// Skewed-tree node throughput under all three frontier drains, plus
/// the determinism gate: completed searches must return bit-identical
/// solutions across drains and across 1/2/8 pinned steal workers.
/// Returns (json, mismatch).
fn steal_bnb_point(budget: usize) -> (Json, bool) {
    let inst = skewed_bnb_instance(11);
    let vs = inst.view_storage();
    let run = |drain: BnbDrain, workers: usize| {
        let mut ws = AllocWorkspace::default();
        let t0 = Instant::now();
        let (sol, nodes, stats) =
            branch_and_bound_view_drained(vs.view(), budget, &mut ws, drain, workers);
        (sol, nodes, stats, t0.elapsed().as_secs_f64())
    };
    let (ser, nodes_ser, _, dt_ser) = run(BnbDrain::Serial, 1);
    let (chk, nodes_chk, _, dt_chk) = run(BnbDrain::Chunked, 0);
    let (stl, nodes_stl, stats, dt_stl) = run(BnbDrain::Steal, 0);

    let mut mismatch = false;
    let mut check = |name: &str, sol: &SelSolution| {
        if ser.optimal
            && sol.optimal
            && (sol.chosen != ser.chosen
                || sol.objective.to_bits() != ser.objective.to_bits())
        {
            eprintln!("STEAL DIVERGENCE: {name} differs from serial drain");
            mismatch = true;
        }
    };
    check("chunked", &chk);
    check("steal(auto)", &stl);
    // pinned worker counts — the schedule changes, the bits must not
    for workers in [1usize, 2, 8] {
        let (sol, _, _, _) = run(BnbDrain::Steal, workers);
        check(&format!("steal({workers}w)"), &sol);
    }

    let nps_ser = nodes_ser as f64 / dt_ser.max(1e-9);
    let nps_chk = nodes_chk as f64 / dt_chk.max(1e-9);
    let nps_stl = nodes_stl as f64 / dt_stl.max(1e-9);
    println!(
        "bnb_skew/15c_4p_4t serial {nodes_ser} nodes ({nps_ser:.0}/s), \
         chunked {nodes_chk} ({nps_chk:.0}/s), \
         steal {nodes_stl} ({nps_stl:.0}/s, {} steals / {} subtrees moved, \
         speedup vs chunked {:.2}x){}",
        stats.steals,
        stats.stolen_items,
        dt_chk / dt_stl.max(1e-9),
        if mismatch { " MISMATCH" } else { "" },
    );
    let mut m = BTreeMap::new();
    m.insert("name".into(), Json::Str("bnb_skew".into()));
    m.insert("clients".into(), Json::Num(15.0));
    m.insert("domains".into(), Json::Num(4.0));
    m.insert("steps".into(), Json::Num(4.0));
    m.insert("node_budget".into(), Json::Num(budget as f64));
    m.insert("nodes_serial".into(), Json::Num(nodes_ser as f64));
    m.insert("nodes_chunked".into(), Json::Num(nodes_chk as f64));
    m.insert("nodes_steal".into(), Json::Num(nodes_stl as f64));
    m.insert("nodes_per_s_serial".into(), Json::Num(nps_ser));
    m.insert("nodes_per_s_chunked".into(), Json::Num(nps_chk));
    m.insert("nodes_per_s_steal".into(), Json::Num(nps_stl));
    m.insert(
        "wallclock_speedup_steal_vs_chunked".into(),
        Json::Num(dt_chk / dt_stl.max(1e-9)),
    );
    // schedule-dependent telemetry (no ns_/per_s suffix → the ci.sh
    // ratchet reports but never gates on these)
    m.insert("steal_workers".into(), Json::Num(stats.workers as f64));
    m.insert("steal_count".into(), Json::Num(stats.steals as f64));
    m.insert("stolen_subtrees".into(), Json::Num(stats.stolen_items as f64));
    m.insert("complete_serial".into(), Json::Bool(ser.optimal));
    m.insert("complete_chunked".into(), Json::Bool(chk.optimal));
    m.insert("complete_steal".into(), Json::Bool(stl.optimal));
    m.insert("chosen_match".into(), Json::Bool(!mismatch));
    (Json::Obj(m), mismatch)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let full = std::env::args().any(|a| a == "--full");
    if std::env::args().any(|a| a == "--steal") {
        // fast standalone mode for `ci.sh --quick`: ONLY the skewed-tree
        // drain comparison + its cross-drain/cross-worker bitwise gate
        println!("== branch-and-bound drain comparison [steal] ==");
        let (steal_json, steal_mismatch) = steal_bnb_point(400_000);
        let mut root = BTreeMap::new();
        root.insert("bench".into(), Json::Str("selection".into()));
        root.insert("mode".into(), Json::Str("steal".into()));
        root.insert("bnb_steal".into(), steal_json);
        let out = Json::Obj(root).to_string_pretty();
        let path = "BENCH_selection.json";
        match fedzero::util::fsx::write_atomic(std::path::Path::new(path), out.as_bytes()) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
        if steal_mismatch {
            eprintln!("branch-and-bound drain/worker equivalence FAILED");
            std::process::exit(1);
        }
        println!("== done ==");
        return;
    }
    let mode = if full {
        "full"
    } else if quick {
        "quick"
    } else {
        "default"
    };
    println!("== selection scaling (Fig 8) [{mode}] ==");

    let mut points: Vec<Point> = Vec::new();

    // (a) clients sweep — evaluation scale measured precisely
    points.push(point("fig8a/100c_10p_60t", 100, 10, 60, 1, 30, 10));
    println!("   paper reports ~0.1 s at this scale (Gurobi)");
    points.push(point("fig8a/1kc_100p_60t", 1_000, 100, 60, 2, 15, 5));

    if !quick {
        points.push(point("fig8a/10kc_1kp_60t", 10_000, 1_000, 60, 2, 7, 3));

        // (b) domains sweep at fixed clients
        for p in [10usize, 100, 1_000] {
            let name = format!("fig8b/10kc_{p}p_60t");
            points.push(point(&name, 10_000, p, 60, 3, 5, 3));
        }
    }

    if full {
        for (c, p, t) in
            [(100_000usize, 10_000usize, 60usize), (100_000, 100_000, 1_440)]
        {
            let name = format!("fig8/{c}c_{p}p_{t}t");
            // reference is far too slow here; paper envelope is 120 s
            let pt = point(&name, c, p, t, 4, 3, 0);
            println!(
                "   (paper envelope at this scale: 120 s; ours: {})",
                fmt_ns(pt.median())
            );
            points.push(pt);
        }
    }

    // --- exact-solver node throughput: serial vs parallel B&B on one
    // seeded instance; completed searches must return identical results
    println!("\n== branch-and-bound serial vs parallel ==");
    let (bnb_json, bnb_mismatch) = bnb_point(if quick { 200_000 } else { 2_000_000 });

    // --- skewed-tree drain comparison: uniform frontier split vs work
    // stealing on a tree where one subtree dwarfs the rest
    println!("\n== branch-and-bound skewed-tree drains ==");
    let (steal_json, steal_mismatch) =
        steal_bnb_point(if quick { 400_000 } else { 2_000_000 });

    // all reference-checked points must have matched
    let mismatches: Vec<&str> = points
        .iter()
        .filter(|p| p.chosen_matches_reference == Some(false))
        .map(|p| p.name.as_str())
        .collect();

    // machine-readable trajectory for cross-PR tracking
    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("selection".into()));
    root.insert("mode".into(), Json::Str(mode.into()));
    root.insert("swap_passes".into(), Json::Num(1.0));
    root.insert(
        "points".into(),
        Json::Arr(points.iter().map(|p| p.to_json()).collect()),
    );
    root.insert("bnb".into(), bnb_json);
    root.insert("bnb_steal".into(), steal_json);
    let out = Json::Obj(root).to_string_pretty();
    let path = "BENCH_selection.json";
    match fedzero::util::fsx::write_atomic(std::path::Path::new(path), out.as_bytes()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    if !mismatches.is_empty() {
        eprintln!("solver equivalence FAILED at: {mismatches:?}");
        std::process::exit(1);
    }
    if bnb_mismatch {
        eprintln!("branch-and-bound serial/parallel equivalence FAILED");
        std::process::exit(1);
    }
    if steal_mismatch {
        eprintln!("branch-and-bound drain/worker equivalence FAILED");
        std::process::exit(1);
    }
    println!("== done ==");
}
