//! Solver micro-benchmarks: the per-domain allocation flow, the dense
//! simplex, and greedy vs exact selection — the costs behind Fig 8 and
//! the ablation "greedy vs Gurobi-style exact" (DESIGN.md §2).

use fedzero::solver::alloc::{AllocClient, AllocProblem};
use fedzero::solver::lp::{Cmp, Lp};
use fedzero::solver::mip::{branch_and_bound, enumerate, greedy, SelClient, SelInstance};
use fedzero::util::bench::{bench, Config};
use fedzero::util::rng::Rng;

fn alloc_problem(c: usize, t: usize, seed: u64) -> AllocProblem {
    let mut rng = Rng::new(seed);
    AllocProblem {
        clients: (0..c)
            .map(|_| {
                let min = rng.range_f64(1.0, 10.0);
                AllocClient {
                    min_batches: min,
                    max_batches: min * 5.0,
                    delta: rng.range_f64(0.05, 0.5),
                    weight: rng.range_f64(0.1, 10.0),
                    spare: (0..t)
                        .map(|_| rng.range_f64(0.0, 40.0) as f32)
                        .collect(),
                }
            })
            .collect(),
        energy: (0..t).map(|_| rng.range_f64(1.0, 14.0) as f32).collect(),
    }
}

fn sel_instance(c: usize, p: usize, t: usize, n: usize, seed: u64) -> SelInstance {
    let mut rng = Rng::new(seed);
    SelInstance {
        n,
        clients: (0..c)
            .map(|_| {
                let m_min = rng.range_f64(2.0, 20.0);
                SelClient {
                    domain: rng.below(p),
                    sigma: rng.range_f64(0.1, 10.0),
                    delta: rng.range_f64(0.05, 0.5),
                    m_min,
                    m_max: m_min * 5.0,
                    spare: (0..t)
                        .map(|_| rng.range_f64(0.0, 40.0) as f32)
                        .collect(),
                }
            })
            .collect(),
        energy: (0..p)
            .map(|_| {
                (0..t).map(|_| rng.range_f64(0.0, 14.0) as f32).collect()
            })
            .collect(),
    }
}

fn main() {
    let cfg = Config::default();
    println!("== solver benches ==");

    // per-domain allocation flow at round-execution scales
    for (c, t) in [(3usize, 60usize), (10, 60), (10, 240), (30, 60)] {
        let p = alloc_problem(c, t, 1);
        bench(&format!("alloc_flow/{c}c_{t}t"), cfg, || {
            p.solve().map(|a| a.objective)
        });
    }

    // dense simplex on the same allocation problem (the cross-check path)
    {
        let p = alloc_problem(3, 12, 2);
        bench("lp_simplex/3c_12t", cfg, || {
            let c_n = p.clients.len();
            let t_n = p.energy.len();
            let nv = c_n * t_n;
            let mut obj = vec![0.0; nv];
            for i in 0..c_n {
                for j in 0..t_n {
                    obj[i * t_n + j] = p.clients[i].weight;
                }
            }
            let mut lp = Lp::new(nv).maximize(&obj);
            for i in 0..c_n {
                let mut row = vec![0.0; nv];
                for j in 0..t_n {
                    row[i * t_n + j] = 1.0;
                }
                lp.constrain(&row, Cmp::Ge, p.clients[i].min_batches);
                lp.constrain(&row, Cmp::Le, p.clients[i].max_batches);
                for j in 0..t_n {
                    lp.upper_bound(i * t_n + j, p.clients[i].spare[j] as f64);
                }
            }
            for j in 0..t_n {
                let mut row = vec![0.0; nv];
                for i in 0..c_n {
                    row[i * t_n + j] = p.clients[i].delta;
                }
                lp.constrain(&row, Cmp::Le, p.energy[j] as f64);
            }
            lp.solve()
        });
    }

    // selection: greedy vs exact at evaluation scale (100 clients)
    let inst = sel_instance(100, 10, 60, 10, 3);
    bench("select_greedy/100c_10p_60t", cfg, || greedy(&inst, 1));
    let quick = fedzero::util::bench::quick();
    bench("select_bnb/100c_10p_60t", quick, || {
        branch_and_bound(&inst, 20_000)
    });

    // tiny instance: enumerate as ground truth
    let tiny = sel_instance(12, 3, 20, 4, 4);
    bench("select_enumerate/12c_choose_4", quick, || enumerate(&tiny));
    println!("== done ==");
}
