//! End-to-end simulation benches, two layers:
//!
//! 1. classic throughput — simulated rounds per wallclock second (the
//!    cost of regenerating Table 3 / Fig 5) for the mock backend and,
//!    outside `--quick`, the PJRT backend;
//! 2. **sim-step microbenches** for the ring-arena loop: median ns per
//!    *idle* (dark-period) step across d_max values — with the
//!    incremental ring advance this must be independent of d_max — a
//!    dark-period SCALING sweep (`ns_per_idle_step_dark` across client
//!    counts up to 100k; with the incremental selection state a fully
//!    dark poll is O(D), so the cost must be flat in C and the
//!    dirty-domain touch counter is hard-asserted to be zero), ns per
//!    round-bearing step, the incremental-vs-fresh divergence gate
//!    (ring view AND attached `IncrSelState` vs fresh builds; exits
//!    non-zero on any decision or quick-gate mismatch), the
//!    **FSM-vs-legacy round-loop gate** (ns/round through the
//!    event-driven state machine vs the legacy batch loop; with no
//!    faults injected the two must be bit-identical in `MetricsLog`,
//!    step totals and final global model), the **hierarchical
//!    aggregation layer** — a 1M-client synthetic round reduced flat vs
//!    through the per-domain tree across domain counts (ns/round,
//!    arena-bytes peak-RSS proxy, bitwise divergence gate) plus a
//!    full-sim `AggMode::Flat` vs `AggMode::Tree` run gate — and the
//!    f32-ring vs historical-f64 window footprint.
//!
//! Results go to rust/BENCH_endtoend.json for cross-PR tracking.
//!
//! Flags: --quick  CI smoke (small points, mock only)
//!        --tree   ONLY the 1M-client flat-vs-tree scaling + divergence
//!                 gate PLUS the skewed-domain stolen-leaf-fill series
//!                 (one giant domain, work-stealing fill at 1/2/8
//!                 pinned workers, steal counts recorded), written to
//!                 rust/BENCH_tree.json (fast enough for
//!                 `ci.sh --quick`; exits 1 on any bit divergence)

use std::collections::BTreeMap;
use std::time::Instant;

use fedzero::client::{ClientInfo, ClientProfile, DeviceType, ModelKind};
use fedzero::config::Scenario;
use fedzero::coordinator::{run_experiment, ExperimentSpec, StrategyKind};
use fedzero::energy::PowerDomain;
use fedzero::fl::{AggMode, MockBackend, TreeAggregator};
use fedzero::selection::arena::SelArena;
use fedzero::selection::baselines::Baseline;
use fedzero::selection::fedzero::{FedZero, SolverKind};
use fedzero::selection::incr::IncrSelState;
use fedzero::selection::ring::{FcBuffers, FcSource, ForecastRing, SeriesSource};
use fedzero::selection::{ClientRoundState, SelectionContext, Strategy};
use fedzero::sim::{ExecMode, SimConfig, Simulation};
use fedzero::trace::forecast::{ErrorLevel, SeriesForecaster};
use fedzero::util::bench::fmt_ns;
use fedzero::util::json::Json;
use fedzero::util::obs;
use fedzero::util::rng::Rng;

fn spec(mock: bool, strategy: StrategyKind) -> ExperimentSpec {
    ExperimentSpec {
        preset: "tiny".into(),
        scenario: Scenario::Global,
        strategy,
        days: 1,
        n_clients: 30,
        n_per_round: 5,
        d_max: 60,
        dataset_scale: 0.15,
        use_mock: mock,
        eval_every: 10,
        eval_subset: 200,
        ..Default::default()
    }
}

fn run_e2e(label: &str, s: &ExperimentSpec, out: &mut Vec<Json>) {
    let t0 = Instant::now();
    match run_experiment(s) {
        Ok(report) => {
            let dt = t0.elapsed().as_secs_f64();
            let rounds = report.metrics.rounds.len();
            println!(
                "bench e2e/{label:<26} {rounds:>5} rounds in {dt:>6.2} s  ({:>7.1} rounds/s, {} train steps, select {:.0} ms)",
                rounds as f64 / dt,
                report.steps_executed,
                report.select_time_ms,
            );
            let mut m = BTreeMap::new();
            m.insert("name".into(), Json::Str(label.into()));
            m.insert("rounds".into(), Json::Num(rounds as f64));
            m.insert("rounds_per_s".into(), Json::Num(rounds as f64 / dt));
            m.insert(
                "select_time_ms".into(),
                Json::Num(report.select_time_ms),
            );
            out.push(Json::Obj(m));
        }
        Err(e) => eprintln!("skipping {label}: {e:#}"),
    }
}

/// Build a mock-backed simulation fixture: `power_w` per domain (0.0 =
/// permanently dark → every step is an idle poll).
fn sim_parts(
    n_clients: usize,
    n_domains: usize,
    power_w: f64,
    horizon: usize,
    realistic_fc: bool,
) -> (Vec<ClientInfo>, Vec<PowerDomain>, Vec<Vec<f64>>, Vec<SeriesForecaster>) {
    let clients: Vec<ClientInfo> = (0..n_clients)
        .map(|i| {
            let p = ClientProfile::new(
                DeviceType::ALL[i % 3],
                ModelKind::Vision,
                10,
                1.0,
            );
            ClientInfo::new(i, i % n_domains, p, (0..60).collect(), 10)
        })
        .collect();
    let domains: Vec<PowerDomain> = (0..n_domains)
        .map(|i| {
            let series = vec![power_w; horizon];
            let fc = if realistic_fc {
                SeriesForecaster::realistic(series.clone(), i as u64, 60.0)
            } else {
                SeriesForecaster::perfect(series.clone())
            };
            PowerDomain::new(i, "d", 800.0, series, fc, 1.0)
        })
        .collect();
    let load: Vec<Vec<f64>> = (0..n_clients).map(|_| vec![0.0; horizon]).collect();
    let load_fc: Vec<SeriesForecaster> = clients
        .iter()
        .map(|c| {
            let series = vec![c.capacity(); horizon];
            if realistic_fc {
                SeriesForecaster::realistic(series, 7, 60.0)
            } else {
                SeriesForecaster::perfect(series)
            }
        })
        .collect();
    (clients, domains, load, load_fc)
}

/// ns per simulated step for a FedZero run over the fixture; returns
/// (ns_per_step, rounds).
fn step_cost(
    n_clients: usize,
    n_domains: usize,
    power_w: f64,
    horizon: usize,
    d_max: usize,
) -> (f64, usize) {
    let (clients, domains, load, load_fc) =
        sim_parts(n_clients, n_domains, power_w, horizon, true);
    let backend = MockBackend::new(n_clients, 8, 0.2, 7);
    let mut fz = FedZero::new(SolverKind::Greedy);
    let cfg = SimConfig {
        horizon,
        n_per_round: 5.min(n_clients),
        d_max,
        eval_every: 50,
        seed: 3,
        step_minutes: 1.0,
    };
    let mut sim = Simulation::new(
        cfg,
        clients,
        domains,
        load,
        load_fc,
        ErrorLevel::Realistic,
        &backend,
        &mut fz,
    );
    let t0 = Instant::now();
    sim.run().unwrap();
    let ns = t0.elapsed().as_nanos() as f64 / horizon as f64;
    (ns, sim.metrics.rounds.len())
}

/// Train-phase cost: one powered fixture where local training dominates
/// the step (large mock model, many selected clients per round), run
/// with the backend shard fan-out forced on or off. Returns (ns per
/// executed round, rounds, total train steps, metrics, final global
/// model) so the caller can both report the speedup and gate on the
/// serial/sharded paths being bit-identical.
fn train_phase_cost(
    parallel: bool,
    quick: bool,
) -> (f64, usize, u64, fedzero::metrics::MetricsLog, Vec<f32>) {
    let n_clients = 48;
    let n_domains = 12;
    let horizon = if quick { 240 } else { 480 };
    let dim = if quick { 4_096 } else { 32_768 };
    let (clients, domains, load, load_fc) =
        sim_parts(n_clients, n_domains, 800.0, horizon, false);
    let mut backend = MockBackend::new(n_clients, dim, 0.2, 7);
    backend.par_min_jobs = if parallel { 1 } else { usize::MAX };
    let mut strat = Baseline::random();
    let cfg = SimConfig {
        horizon,
        n_per_round: 24,
        d_max: 30,
        eval_every: 50,
        seed: 3,
        step_minutes: 1.0,
    };
    let mut sim = Simulation::new(
        cfg,
        clients,
        domains,
        load,
        load_fc,
        ErrorLevel::Realistic,
        &backend,
        &mut strat,
    );
    let t0 = Instant::now();
    sim.run().unwrap();
    let dt = t0.elapsed().as_nanos() as f64;
    let rounds = sim.metrics.rounds.len();
    let steps = sim.steps_executed();
    let global = std::mem::take(&mut sim.final_global);
    (dt / rounds.max(1) as f64, rounds, steps, sim.metrics, global)
}

/// Round-loop cost under one execution path: the same powered fixture
/// run through the legacy batch loop or the event-driven round state
/// machine. Returns (ns per executed round, rounds, train steps,
/// metrics, final global model) so the caller can report the event
/// queue's overhead AND gate on the two paths being bit-identical (the
/// FSM determinism criterion: with no faults injected the state
/// machine must reproduce the legacy `MetricsLog` exactly).
fn fsm_phase_cost(
    exec: ExecMode,
    agg: AggMode,
    quick: bool,
) -> (f64, usize, u64, fedzero::metrics::MetricsLog, Vec<f32>) {
    let n_clients = 36;
    let n_domains = 9;
    let horizon = if quick { 300 } else { 900 };
    let (clients, domains, load, load_fc) =
        sim_parts(n_clients, n_domains, 500.0, horizon, true);
    let backend = MockBackend::new(n_clients, 2_048, 0.2, 7);
    let mut fz = FedZero::new(SolverKind::Greedy);
    let cfg = SimConfig {
        horizon,
        n_per_round: 8,
        d_max: 45,
        eval_every: 50,
        seed: 5,
        step_minutes: 1.0,
    };
    let mut sim = Simulation::new(
        cfg,
        clients,
        domains,
        load,
        load_fc,
        ErrorLevel::Realistic,
        &backend,
        &mut fz,
    );
    sim.exec = exec;
    sim.agg = agg;
    if agg == AggMode::Tree {
        // the 9-domain fixture sits below the real fan-out gates; pin
        // them open so the tree run genuinely exercises the parallel
        // leaf tier (results are bit-identical either way)
        sim.tree.par_groups_min = 1;
        sim.tree.par_work_min = 0;
    }
    let t0 = Instant::now();
    sim.run().unwrap();
    let dt = t0.elapsed().as_nanos() as f64;
    let rounds = sim.metrics.rounds.len();
    let steps = sim.steps_executed();
    let global = std::mem::take(&mut sim.final_global);
    (dt / rounds.max(1) as f64, rounds, steps, sim.metrics, global)
}

/// A permanently dark, constant-spare forecast source for the O(D)
/// polling bench: the SOURCE holds no per-entity series. (The ring
/// itself still allocates its mirrored C×2·d_max f32 spare arena once at
/// rebuild — that resident footprint is inherent to the ring design and
/// is why the sweep below caps d_max; see `window_footprint` for the
/// full 1440-step numbers.)
struct DarkSource {
    domains: usize,
    clients: usize,
    cap: f64,
}

impl FcSource for DarkSource {
    fn n_domains(&self) -> usize {
        self.domains
    }

    fn n_clients(&self) -> usize {
        self.clients
    }

    fn energy_at(&self, _t0: usize, _t: usize, _p: usize) -> f64 {
        0.0
    }

    fn spare_at(&self, _t0: usize, _t: usize, _i: usize) -> f64 {
        self.cap
    }
}

/// Steady-state cost of one fully dark idle poll at the selection layer
/// (ring advance + incremental-state patch + FedZero quick gate) —
/// O(D) per step: flat in the client count is the acceptance criterion.
/// Returns ns/step; also hard-asserts the structural guarantee (no
/// client touched by any dark advance).
fn dark_poll_ns(n_clients: usize, n_domains: usize, d_max: usize, steps: usize) -> f64 {
    let clients: Vec<ClientInfo> = (0..n_clients)
        .map(|i| {
            let p = ClientProfile::new(
                DeviceType::ALL[i % 3],
                ModelKind::Vision,
                10,
                1.0,
            );
            ClientInfo::new(i, i % n_domains, p, (0..20).collect(), 10)
        })
        .collect();
    let states = vec![ClientRoundState::default(); n_clients];
    let domains: Vec<PowerDomain> = (0..n_domains)
        .map(|i| {
            PowerDomain::new(
                i,
                "d",
                800.0,
                vec![0.0; 4],
                SeriesForecaster::perfect(vec![0.0; 4]),
                1.0,
            )
        })
        .collect();
    let src = DarkSource { domains: n_domains, clients: n_clients, cap: 25.0 };
    let spare_now: Vec<f64> = Vec::new(); // FedZero never reads it
    let mut ring = ForecastRing::new();
    ring.rebuild(&src, 0, d_max);
    let mut incr = IncrSelState::new();
    incr.rebuild(&clients, &states, ring.view());
    let mut fz = FedZero::new(SolverKind::Greedy);
    let mut rng = Rng::new(9);
    let t0 = Instant::now();
    for step in 1..=steps {
        incr.advance(&mut ring, &src);
        assert_eq!(
            incr.last_advance_touched(),
            0,
            "dark advance touched client state (step {step})"
        );
        let ctx = SelectionContext {
            now: step,
            n: 10,
            d_max,
            clients: &clients,
            states: &states,
            domains: &domains,
            fc: ring.view(),
            incr: Some(&incr),
            spare_now: &spare_now,
        };
        let d = fz.select(&ctx, &mut rng);
        assert!(d.wait, "dark poll selected a round");
    }
    t0.elapsed().as_nanos() as f64 / steps as f64
}

/// Ring/incremental-vs-fresh divergence gate: drive FedZero over N
/// consecutive incrementally advanced windows — once over the bare ring
/// view, once with the incremental selection state attached — and assert
/// each decision AND quick-gate count equals the fresh-build reference.
/// Returns the number of mismatches (0 = green).
fn divergence_gate(seed: u64, steps: usize) -> usize {
    let mut rng = Rng::new(seed);
    let n_domains = 4;
    let n_clients = 24;
    let d_max = 40;
    let horizon = d_max + steps + 2;
    let clients: Vec<ClientInfo> = (0..n_clients)
        .map(|i| {
            let p = ClientProfile::new(
                DeviceType::ALL[i % 3],
                ModelKind::Vision,
                10,
                1.0,
            );
            ClientInfo::new(i, i % n_domains, p, (0..50).collect(), 10)
        })
        .collect();
    let mut states = vec![ClientRoundState::default(); n_clients];
    for s in states.iter_mut() {
        s.sigma = rng.range_f64(0.1, 10.0);
    }
    let domains: Vec<PowerDomain> = (0..n_domains)
        .map(|i| {
            let series = vec![200.0; horizon];
            PowerDomain::new(
                i,
                "d",
                800.0,
                series.clone(),
                SeriesForecaster::perfect(series),
                1.0,
            )
        })
        .collect();
    let caps: Vec<f64> = clients.iter().map(|c| c.capacity()).collect();
    // sine power with dark stretches + realistic forecast error — the
    // adversarial case for incremental advance
    let src = SeriesSource {
        energy: (0..n_domains)
            .map(|p| {
                let base = rng.range_f64(2.0, 12.0);
                let series: Vec<f64> = (0..horizon)
                    .map(|t| (base * ((t as f64 / 13.0).sin())).max(0.0))
                    .collect();
                SeriesForecaster::realistic(series, seed ^ p as u64, 60.0)
            })
            .collect(),
        spare: caps
            .iter()
            .enumerate()
            .map(|(i, &cap)| {
                let series: Vec<f64> =
                    (0..horizon).map(|_| cap * rng.range_f64(0.3, 1.1)).collect();
                SeriesForecaster::realistic(series, seed ^ (100 + i as u64), 60.0)
            })
            .collect(),
        caps,
    };
    let spare_now: Vec<f64> =
        clients.iter().map(|c| c.capacity() * 0.8).collect();
    let mut ring = ForecastRing::new();
    ring.rebuild(&src, 0, d_max);
    let mut incr = IncrSelState::new();
    incr.rebuild(&clients, &states, ring.view());
    let mut mismatches = 0usize;
    for step in 0..steps {
        if step > 0 {
            incr.advance(&mut ring, &src);
        }
        let fresh = FcBuffers::from_source(&src, 0, step, d_max);
        let select = |fc: fedzero::selection::ring::FcView<'_>,
                      state: Option<&IncrSelState>| {
            let ctx = SelectionContext {
                now: step,
                n: 5,
                d_max,
                clients: &clients,
                states: &states,
                domains: &domains,
                fc,
                incr: state,
                spare_now: &spare_now,
            };
            let quick = SelArena::quick_eligible_count(&ctx);
            let mut srng = Rng::new(42);
            (FedZero::new(SolverKind::Greedy).select(&ctx, &mut srng), quick)
        };
        let (d_ring, q_ring) = select(ring.view(), None);
        let (d_incr, q_incr) = select(ring.view(), Some(&incr));
        let (d_fresh, q_fresh) = select(fresh.view(), None);
        if d_ring != d_fresh {
            eprintln!(
                "RING DIVERGENCE at step {step}: ring {:?} vs fresh {:?}",
                d_ring.clients, d_fresh.clients
            );
            mismatches += 1;
        }
        if d_incr != d_fresh || q_incr != q_fresh || q_ring != q_fresh {
            eprintln!(
                "INCR DIVERGENCE at step {step}: incr {:?} (quick {q_incr}) \
                 vs fresh {:?} (quick {q_fresh}, ring quick {q_ring})",
                d_incr.clients, d_fresh.clients
            );
            mismatches += 1;
        }
    }
    mismatches
}

/// Mirrored f32 ring bytes vs the historical peak (f64 window buffers in
/// the engine PLUS the per-select f64 arena copy).
fn window_footprint(clients: usize, domains: usize, d_max: usize) -> (u64, u64) {
    let rows = (clients + domains) as u64;
    let ring_f32 = rows * 2 * d_max as u64 * 4;
    let historical_f64 = rows * d_max as u64 * 8 * 2;
    (ring_f32, historical_f64)
}

/// Hierarchical-aggregation scaling: one synthetic round of `n_clients`
/// updates (dim `dim`) reduced flat (serial oracle schedule) and through
/// the per-domain tree, across domain counts. Updates live in ONE flat
/// backing buffer (1M × dim f32) with a deterministic hash fill, so the
/// point measures aggregation, not setup. Returns the JSON scaling
/// points, the bitwise flat-vs-tree mismatch count (0 = green) and the
/// tree's peak arena bytes (the peak-RSS proxy — the only memory the
/// tree layer adds over flat). Domain counts below the real
/// `TREE_GROUPS`/`TREE_WORK` gates honestly stay serial (speedup ~1).
fn tree_scaling(
    n_clients: usize,
    dim: usize,
    domain_counts: &[usize],
    reps: usize,
) -> (Vec<Json>, usize, usize) {
    let mut buf = vec![0.0f32; n_clients * dim];
    for (i, v) in buf.iter_mut().enumerate() {
        *v = ((i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 40) as f32 * 1e-4;
    }
    let updates: Vec<&[f32]> = buf.chunks_exact(dim).collect();
    let weights: Vec<f32> =
        (0..n_clients).map(|i| ((i * 37) % 100 + 1) as f32).collect();

    let mut flat = TreeAggregator::new();
    let mut tree = TreeAggregator::new();
    let mut out_f = Vec::new();
    let mut out_t = Vec::new();
    let mut points = Vec::new();
    let mut mismatches = 0usize;
    for &d in domain_counts {
        let domains: Vec<usize> = (0..n_clients).map(|i| i % d.max(1)).collect();
        let mut best_f = f64::MAX;
        let mut best_t = f64::MAX;
        for _ in 0..reps.max(1) {
            let t0 = Instant::now();
            flat.aggregate_into(AggMode::Flat, &domains, &updates, &weights, &mut out_f)
                .unwrap();
            best_f = best_f.min(t0.elapsed().as_nanos() as f64);
            let t1 = Instant::now();
            tree.aggregate_into(AggMode::Tree, &domains, &updates, &weights, &mut out_t)
                .unwrap();
            best_t = best_t.min(t1.elapsed().as_nanos() as f64);
        }
        if out_f.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            != out_t.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
        {
            eprintln!("TREE DIVERGENCE: tree != flat at {d} domains");
            mismatches += 1;
        }
        let speedup = best_f / best_t.max(1.0);
        println!(
            "tree/{n_clients}c_d{d:<6} flat {:>12}  tree {:>12} per round (speedup {speedup:.2}x, arena {:.1} MB)",
            fmt_ns(best_f),
            fmt_ns(best_t),
            tree.arena_bytes() as f64 / 1e6
        );
        let mut m = BTreeMap::new();
        m.insert("name".into(), Json::Str(format!("tree_d{d}")));
        m.insert("clients".into(), Json::Num(n_clients as f64));
        m.insert("dim".into(), Json::Num(dim as f64));
        m.insert("domains".into(), Json::Num(d as f64));
        m.insert("ns_per_round_flat".into(), Json::Num(best_f));
        m.insert("ns_per_round_tree".into(), Json::Num(best_t));
        m.insert("speedup".into(), Json::Num(speedup));
        m.insert("arena_bytes".into(), Json::Num(tree.arena_bytes() as f64));
        points.push(Json::Obj(m));
    }
    (points, mismatches, tree.peak_arena_bytes())
}

/// Skewed-domain leaf fill: one giant domain holds ~90% of the round's
/// updates, the rest are singletons. A static per-worker group split
/// would pin the singleton tail behind whichever worker also drew the
/// giant row; the work-stealing fill (`util::par::steal`) lets idle
/// workers drain the tail while one owns the monster. The giant row
/// itself is a single work unit, so the tail (~10% of the mass) bounds
/// the speedup — the load-bearing claims are (a) flat vs stolen tree
/// stays bit-identical at 1/2/8 pinned workers and (b) the recorded
/// steal counts prove rows actually moved. Returns the JSON points and
/// the bitwise mismatch count (0 = green).
fn tree_skew(n_clients: usize, dim: usize, reps: usize) -> (Vec<Json>, usize) {
    let mut buf = vec![0.0f32; n_clients * dim];
    for (i, v) in buf.iter_mut().enumerate() {
        *v = ((i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15) >> 40) as f32 * 1e-4;
    }
    let updates: Vec<&[f32]> = buf.chunks_exact(dim).collect();
    let weights: Vec<f32> =
        (0..n_clients).map(|i| ((i * 37) % 100 + 1) as f32).collect();
    let giant = n_clients * 9 / 10;
    let domains: Vec<usize> = (0..n_clients)
        .map(|i| if i < giant { 0 } else { i - giant + 1 })
        .collect();

    let mut flat = TreeAggregator::new();
    let mut out_f = Vec::new();
    let mut best_f = f64::MAX;
    for _ in 0..reps.max(1) {
        let t0 = Instant::now();
        flat.aggregate_into(AggMode::Flat, &domains, &updates, &weights, &mut out_f)
            .unwrap();
        best_f = best_f.min(t0.elapsed().as_nanos() as f64);
    }
    let flat_bits: Vec<u32> = out_f.iter().map(|x| x.to_bits()).collect();

    let mut points = Vec::new();
    let mut mismatches = 0usize;
    let mut ns_1w = f64::MAX;
    for workers in [1usize, 2, 8] {
        let mut tree = TreeAggregator::new();
        tree.par_groups_min = 1;
        tree.par_work_min = 0;
        tree.par_workers = workers;
        let mut out_t = Vec::new();
        let mut best_t = f64::MAX;
        for _ in 0..reps.max(1) {
            let t0 = Instant::now();
            tree.aggregate_into(AggMode::Tree, &domains, &updates, &weights, &mut out_t)
                .unwrap();
            best_t = best_t.min(t0.elapsed().as_nanos() as f64);
        }
        if out_t.iter().map(|x| x.to_bits()).collect::<Vec<_>>() != flat_bits {
            eprintln!("TREE-SKEW DIVERGENCE: stolen tree != flat at {workers} workers");
            mismatches += 1;
        }
        if workers == 1 {
            ns_1w = best_t;
        }
        let speedup = ns_1w / best_t.max(1.0);
        println!(
            "tree_skew/{n_clients}c_giant90_{workers}w flat {:>12}  tree {:>12} per round \
             (vs 1w {speedup:.2}x, {} steals / {} rows moved)",
            fmt_ns(best_f),
            fmt_ns(best_t),
            tree.steal_stats.steals,
            tree.steal_stats.stolen_items,
        );
        let mut m = BTreeMap::new();
        m.insert("name".into(), Json::Str(format!("tree_skew_{workers}w")));
        m.insert("clients".into(), Json::Num(n_clients as f64));
        m.insert("dim".into(), Json::Num(dim as f64));
        m.insert("giant_domain_clients".into(), Json::Num(giant as f64));
        m.insert("workers".into(), Json::Num(workers as f64));
        m.insert("ns_per_round_flat".into(), Json::Num(best_f));
        m.insert("ns_per_round_tree".into(), Json::Num(best_t));
        m.insert("speedup_vs_1w".into(), Json::Num(speedup));
        // schedule-dependent telemetry (no ns_/per_s suffix → reported,
        // never gated by the ci.sh ratchet)
        m.insert("steal_count".into(), Json::Num(tree.steal_stats.steals as f64));
        m.insert(
            "stolen_rows".into(),
            Json::Num(tree.steal_stats.stolen_items as f64),
        );
        points.push(Json::Obj(m));
    }
    (points, mismatches)
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    // telemetry on for the whole bench: every bitwise gate below doubles
    // as proof the probes change no output, and the snapshot feeds the
    // phase-percentile columns of the JSON
    obs::set_enabled(true);
    obs::reset();
    if std::env::args().any(|a| a == "--tree") {
        // fast standalone mode for `ci.sh --quick`: ONLY the 1M-client
        // flat-vs-tree scaling series + the skewed-domain stolen-fill
        // series, each with a bitwise divergence gate
        println!("== hierarchical aggregation [tree] ==");
        let (points, mismatches, peak) =
            tree_scaling(1_000_000, 8, &[1, 64, 4_096], 2);
        println!("\n== skewed-domain stolen leaf fill ==");
        let (skew_points, skew_mismatches) = tree_skew(1_000_000, 8, 2);
        let mut root = BTreeMap::new();
        root.insert("bench".into(), Json::Str("tree".into()));
        root.insert("mode".into(), Json::Str("tree".into()));
        root.insert("tree".into(), Json::Arr(points));
        root.insert("tree_skew".into(), Json::Arr(skew_points));
        root.insert(
            "tree_divergence_mismatches".into(),
            Json::Num((mismatches + skew_mismatches) as f64),
        );
        root.insert("peak_arena_bytes".into(), Json::Num(peak as f64));
        // shard-fill latency distribution from the obs layer across all
        // the tree rounds above (the _ns keys join the ratchet once a
        // baseline is armed; arena_reuses is informational)
        let s = obs::snapshot();
        root.insert(
            "shard_fill_p50_ns".into(),
            Json::Num(s.hist_percentile(obs::Hist::ShardFillNs, 50.0)),
        );
        root.insert(
            "shard_fill_p99_ns".into(),
            Json::Num(s.hist_percentile(obs::Hist::ShardFillNs, 99.0)),
        );
        root.insert(
            "arena_reuses".into(),
            Json::Num(s.ctr(obs::Ctr::TreeArenaReuses) as f64),
        );
        let out = Json::Obj(root).to_string_pretty();
        let path = "BENCH_tree.json";
        match fedzero::util::fsx::write_atomic(std::path::Path::new(path), out.as_bytes()) {
            Ok(()) => println!("wrote {path}"),
            Err(e) => eprintln!("could not write {path}: {e}"),
        }
        if mismatches + skew_mismatches > 0 {
            eprintln!(
                "tree-vs-flat equivalence FAILED ({} mismatches)",
                mismatches + skew_mismatches
            );
            std::process::exit(1);
        }
        println!("== done ==");
        return;
    }
    let mode = if quick { "quick" } else { "default" };
    println!("== end-to-end benches [{mode}] ==");

    let mut e2e = Vec::new();
    run_e2e("mock_fedzero", &spec(true, StrategyKind::FedZero), &mut e2e);
    run_e2e("mock_random", &spec(true, StrategyKind::Random), &mut e2e);
    if !quick {
        run_e2e("xla_fedzero", &spec(false, StrategyKind::FedZero), &mut e2e);
        run_e2e(
            "xla_random_1.3n",
            &spec(false, StrategyKind::RandomOver),
            &mut e2e,
        );
    }

    // --- idle (dark-period) step cost vs d_max: the ring advance makes
    // this flat in d_max (historically it scaled with C·d_max) ---
    println!("\n== idle-step cost (all-dark horizon, FedZero polling) ==");
    let (idle_clients, idle_horizon) = if quick { (300, 800) } else { (1_000, 2_000) };
    let d_maxes: &[usize] = if quick { &[60, 240] } else { &[60, 240, 960] };
    let mut idle_points = Vec::new();
    for &d_max in d_maxes {
        let (ns, rounds) = step_cost(idle_clients, 10, 0.0, idle_horizon, d_max);
        assert_eq!(rounds, 0, "dark sim executed rounds?");
        println!(
            "idle_step/{idle_clients}c_10p_dmax{d_max:<4} {:>12} per step",
            fmt_ns(ns)
        );
        let mut m = BTreeMap::new();
        m.insert("clients".into(), Json::Num(idle_clients as f64));
        m.insert("domains".into(), Json::Num(10.0));
        m.insert("d_max".into(), Json::Num(d_max as f64));
        m.insert("ns_per_idle_step".into(), Json::Num(ns));
        idle_points.push(Json::Obj(m));
    }

    // --- dark-period polling scaling: the O(D) acceptance point. The
    // per-step cost must be flat in C (1k → 100k clients) because a
    // fully dark advance touches domain counters only — the structural
    // guarantee is hard-asserted inside dark_poll_ns via the
    // dirty-domain touch counter; the numbers here track the trajectory.
    println!("\n== dark-period polling (selection layer, all domains dead) ==");
    let dark_clients: &[usize] =
        if quick { &[1_000, 10_000] } else { &[1_000, 10_000, 100_000] };
    let dark_steps = if quick { 500 } else { 1_500 };
    // 8 h window: big enough to exercise the √d_max bucket machinery
    // (B=22), small enough that the 100k point's mirrored spare arena
    // stays ~384 MB instead of the 1.15 GB a 1440-step ring costs (the
    // flatness criterion is in C at fixed d_max, not in d_max)
    let dark_d_max = if quick { 240 } else { 480 };
    let mut dark_points = Vec::new();
    let mut dark_ns = Vec::new();
    for &c in dark_clients {
        let ns = dark_poll_ns(c, 10, dark_d_max, dark_steps);
        println!(
            "idle_dark/{c}c_10p_dmax{dark_d_max} {:>12} per idle step",
            fmt_ns(ns)
        );
        let mut m = BTreeMap::new();
        m.insert("name".into(), Json::Str(format!("dark_{c}c")));
        m.insert("clients".into(), Json::Num(c as f64));
        m.insert("domains".into(), Json::Num(10.0));
        m.insert("d_max".into(), Json::Num(dark_d_max as f64));
        m.insert("ns_per_idle_step_dark".into(), Json::Num(ns));
        dark_points.push(Json::Obj(m));
        dark_ns.push(ns);
    }
    if let (Some(&first), Some(&last)) = (dark_ns.first(), dark_ns.last()) {
        let ratio = last / first.max(1.0);
        println!(
            "dark-poll flatness: {:.2}x from {}c to {}c {}",
            ratio,
            dark_clients.first().unwrap(),
            dark_clients.last().unwrap(),
            if ratio < 3.0 { "(flat — ok)" } else { "(WARN: not flat in C)" }
        );
    }

    // --- round-bearing step cost (powered horizon) ---
    println!("\n== round-step cost (powered horizon) ==");
    let (ns_round, rounds) = step_cost(60, 6, 300.0, if quick { 600 } else { 1_500 }, 60);
    println!(
        "round_step/60c_6p_dmax60    {:>12} per step ({rounds} rounds)",
        fmt_ns(ns_round)
    );

    // --- train-phase cost: serial vs sharded local training ---
    // (the serial/sharded runs must be bit-identical — gated below like
    // the ring divergence)
    println!("\n== train-phase cost (48c/12p, 24 per round, big mock model) ==");
    let (ns_train_ser, tr_rounds, tr_steps, m_ser, g_ser) =
        train_phase_cost(false, quick);
    let (ns_train_par, _, tr_steps_par, m_par, g_par) =
        train_phase_cost(true, quick);
    let train_speedup = ns_train_ser / ns_train_par.max(1.0);
    println!(
        "train_phase/serial          {:>12} per round ({tr_rounds} rounds, {tr_steps} steps)",
        fmt_ns(ns_train_ser)
    );
    println!(
        "train_phase/sharded         {:>12} per round (speedup {train_speedup:.2}x)",
        fmt_ns(ns_train_par)
    );
    let train_diverged = m_ser != m_par
        || tr_steps != tr_steps_par
        || g_ser.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            != g_par.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    if train_diverged {
        eprintln!("TRAIN DIVERGENCE: sharded training != serial training");
    }

    // --- round-loop cost: legacy batch loop vs event-driven FSM ---
    // (the no-fault FSM run must be bit-identical to the legacy loop —
    // gated below like the ring and train divergences)
    println!("\n== round-loop cost (36c/9p, legacy vs event-driven FSM) ==");
    let (ns_loop_leg, loop_rounds, loop_steps_leg, m_leg, g_leg) =
        fsm_phase_cost(ExecMode::Legacy, AggMode::Tree, quick);
    let (ns_loop_fsm, _, loop_steps_fsm, m_fsm, g_fsm) =
        fsm_phase_cost(ExecMode::Fsm, AggMode::Tree, quick);
    println!(
        "round_loop/legacy           {:>12} per round ({loop_rounds} rounds, {loop_steps_leg} steps)",
        fmt_ns(ns_loop_leg)
    );
    println!(
        "round_loop/fsm              {:>12} per round (event-queue overhead {:+.1}%)",
        fmt_ns(ns_loop_fsm),
        (ns_loop_fsm / ns_loop_leg.max(1.0) - 1.0) * 100.0
    );
    let fsm_diverged = m_leg != m_fsm
        || loop_steps_leg != loop_steps_fsm
        || g_leg.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            != g_fsm.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    if fsm_diverged {
        eprintln!("FSM DIVERGENCE: event-driven round loop != legacy loop");
    }

    // --- hierarchical aggregation: 1M-client flat-vs-tree scaling +
    // bitwise gate, and a full-sim Flat-vs-Tree run gate ---
    println!("\n== hierarchical aggregation (1M-client synthetic round) ==");
    let tree_domains: &[usize] =
        if quick { &[1, 64, 4_096] } else { &[1, 64, 4_096, 65_536] };
    let (tree_points, tree_mismatches, tree_peak) =
        tree_scaling(1_000_000, 8, tree_domains, if quick { 2 } else { 3 });
    let (_, _, run_steps_fl, m_run_fl, g_run_fl) =
        fsm_phase_cost(ExecMode::Fsm, AggMode::Flat, quick);
    let (_, _, run_steps_tr, m_run_tr, g_run_tr) =
        fsm_phase_cost(ExecMode::Fsm, AggMode::Tree, quick);
    let tree_run_diverged = m_run_fl != m_run_tr
        || run_steps_fl != run_steps_tr
        || g_run_fl.iter().map(|x| x.to_bits()).collect::<Vec<_>>()
            != g_run_tr.iter().map(|x| x.to_bits()).collect::<Vec<_>>();
    if tree_run_diverged {
        eprintln!("TREE RUN DIVERGENCE: AggMode::Tree sim != AggMode::Flat sim");
    }

    // --- ring-vs-fresh divergence gate ---
    println!("\n== ring-vs-fresh divergence gate ==");
    let gate_steps = if quick { 120 } else { 400 };
    let mismatches = divergence_gate(11, gate_steps);
    println!(
        "ring gate: {gate_steps} steps, {mismatches} mismatches {}",
        if mismatches == 0 { "(ok)" } else { "(FAIL)" }
    );

    // --- window footprint: mirrored f32 ring vs historical f64 peak ---
    let (ring_b, hist_b) = window_footprint(100_000, 100_000, 1_440);
    println!(
        "\nwindow footprint @100k clients/100k domains/1440 steps: ring f32 {:.2} GB vs historical f64 peak {:.2} GB",
        ring_b as f64 / 1e9,
        hist_b as f64 / 1e9
    );

    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("endtoend".into()));
    root.insert("mode".into(), Json::Str(mode.into()));
    root.insert("e2e".into(), Json::Arr(e2e));
    root.insert("idle_steps".into(), Json::Arr(idle_points));
    root.insert("idle_dark".into(), Json::Arr(dark_points));
    {
        let mut m = BTreeMap::new();
        m.insert("clients".into(), Json::Num(60.0));
        m.insert("domains".into(), Json::Num(6.0));
        m.insert("ns_per_step".into(), Json::Num(ns_round));
        m.insert("rounds".into(), Json::Num(rounds as f64));
        root.insert("round_steps".into(), Json::Obj(m));
    }
    {
        let mut m = BTreeMap::new();
        m.insert("clients".into(), Json::Num(100_000.0));
        m.insert("domains".into(), Json::Num(100_000.0));
        m.insert("d_max".into(), Json::Num(1_440.0));
        m.insert("ring_f32_bytes".into(), Json::Num(ring_b as f64));
        m.insert("historical_f64_bytes".into(), Json::Num(hist_b as f64));
        root.insert("arena_bytes".into(), Json::Obj(m));
    }
    {
        let mut m = BTreeMap::new();
        m.insert("clients".into(), Json::Num(48.0));
        m.insert("n_per_round".into(), Json::Num(24.0));
        m.insert("rounds".into(), Json::Num(tr_rounds as f64));
        m.insert("train_steps".into(), Json::Num(tr_steps as f64));
        m.insert("ns_per_round_serial".into(), Json::Num(ns_train_ser));
        m.insert("ns_per_round_sharded".into(), Json::Num(ns_train_par));
        m.insert("speedup".into(), Json::Num(train_speedup));
        root.insert("train_phase".into(), Json::Obj(m));
    }
    {
        let mut m = BTreeMap::new();
        m.insert("clients".into(), Json::Num(36.0));
        m.insert("domains".into(), Json::Num(9.0));
        m.insert("rounds".into(), Json::Num(loop_rounds as f64));
        m.insert("ns_per_round_legacy".into(), Json::Num(ns_loop_leg));
        m.insert("ns_per_round_fsm".into(), Json::Num(ns_loop_fsm));
        root.insert("round_loop".into(), Json::Obj(m));
    }
    root.insert(
        "train_divergence".into(),
        Json::Num(if train_diverged { 1.0 } else { 0.0 }),
    );
    root.insert(
        "fsm_divergence".into(),
        Json::Num(if fsm_diverged { 1.0 } else { 0.0 }),
    );
    root.insert(
        "ring_divergence_mismatches".into(),
        Json::Num(mismatches as f64),
    );
    root.insert("tree".into(), Json::Arr(tree_points));
    root.insert(
        "tree_divergence_mismatches".into(),
        Json::Num(tree_mismatches as f64),
    );
    root.insert(
        "tree_run_divergence".into(),
        Json::Num(if tree_run_diverged { 1.0 } else { 0.0 }),
    );
    root.insert("tree_peak_arena_bytes".into(), Json::Num(tree_peak as f64));
    // round-phase latency percentiles from the obs layer across every
    // simulated round above (the _ns keys join the ratchet once armed)
    let s = obs::snapshot();
    for (key, h) in [
        ("round_p50_ns", obs::Hist::RoundNs),
        ("round_p99_ns", obs::Hist::RoundNs),
        ("aggregate_p50_ns", obs::Hist::AggregateNs),
        ("aggregate_p99_ns", obs::Hist::AggregateNs),
    ] {
        let q = if key.ends_with("p50_ns") { 50.0 } else { 99.0 };
        root.insert(key.into(), Json::Num(s.hist_percentile(h, q)));
    }
    let out = Json::Obj(root).to_string_pretty();
    let path = "BENCH_endtoend.json";
    match fedzero::util::fsx::write_atomic(std::path::Path::new(path), out.as_bytes()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    if mismatches > 0 {
        eprintln!("ring-vs-fresh equivalence FAILED ({mismatches} mismatches)");
        std::process::exit(1);
    }
    if train_diverged {
        eprintln!("serial-vs-sharded training equivalence FAILED");
        std::process::exit(1);
    }
    if fsm_diverged {
        eprintln!("FSM-vs-legacy round-loop equivalence FAILED");
        std::process::exit(1);
    }
    if tree_mismatches > 0 {
        eprintln!("tree-vs-flat equivalence FAILED ({tree_mismatches} mismatches)");
        std::process::exit(1);
    }
    if tree_run_diverged {
        eprintln!("tree-vs-flat full-sim equivalence FAILED");
        std::process::exit(1);
    }
    println!("== done ==");
}
