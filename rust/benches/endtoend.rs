//! End-to-end throughput benches: simulated rounds per wallclock second —
//! the cost of regenerating Table 3 / Fig 5 — for the mock backend (pure
//! L3 cost) and the PJRT backend (L3 + real compute).

use std::time::Instant;

use fedzero::config::Scenario;
use fedzero::coordinator::{run_experiment, ExperimentSpec, StrategyKind};

fn spec(mock: bool, strategy: StrategyKind) -> ExperimentSpec {
    ExperimentSpec {
        preset: "tiny".into(),
        scenario: Scenario::Global,
        strategy,
        days: 1,
        n_clients: 30,
        n_per_round: 5,
        d_max: 60,
        dataset_scale: 0.15,
        use_mock: mock,
        eval_every: 10,
        eval_subset: 200,
        ..Default::default()
    }
}

fn run(label: &str, s: &ExperimentSpec) {
    let t0 = Instant::now();
    match run_experiment(s) {
        Ok(report) => {
            let dt = t0.elapsed().as_secs_f64();
            let rounds = report.metrics.rounds.len();
            println!(
                "bench e2e/{label:<26} {rounds:>5} rounds in {dt:>6.2} s  ({:>7.1} rounds/s, {} train steps, select {:.0} ms)",
                rounds as f64 / dt,
                report.steps_executed,
                report.select_time_ms,
            );
        }
        Err(e) => eprintln!("skipping {label}: {e:#}"),
    }
}

fn main() {
    println!("== end-to-end benches (1 simulated day, 30 clients) ==");
    run("mock_fedzero", &spec(true, StrategyKind::FedZero));
    run("mock_random", &spec(true, StrategyKind::Random));
    run("xla_fedzero", &spec(false, StrategyKind::FedZero));
    run("xla_random_1.3n", &spec(false, StrategyKind::RandomOver));
    println!("== done ==");
}
