//! Fault-injection (chaos) benches + CI gates.
//!
//! Gates three robustness properties of the event-driven round engine:
//!
//! 1. **seeded-chaos determinism** — the same `ChaosSpec` + seed run
//!    twice yields a bit-identical `MetricsLog` (compared structurally
//!    AND as serialized JSON text): fault draws are pure functions of
//!    (seed, client, round start), never of wall clock or scheduling;
//! 2. **fault visibility** — the injector actually injects: with a
//!    forced stale-update schedule against a semi-synchronous deadline
//!    the run must meter rejected updates, deadline-closed rounds and
//!    straggler waste, while the validation path stays clean;
//! 3. **worker-count determinism under faults** — a campaign carrying
//!    a chaos axis is byte-identical at 1, 2 and 8 workers, and every
//!    cell carries the `rejected_updates` / `timeout_rounds` columns.
//!
//! Plus throughput: ns per simulated step with the injector on vs off
//! (the price of the event queue + fault plans on a powered horizon).
//!
//! Results go to rust/BENCH_chaos.json; any gate failure exits non-zero
//! (wired into ci.sh --quick beside the campaign gates).
//!
//! Flags: --quick  CI smoke (short horizon)

use std::collections::BTreeMap;
use std::time::Instant;

use fedzero::client::{ClientInfo, ClientProfile, DeviceType, ModelKind};
use fedzero::coordinator::StrategyKind;
use fedzero::energy::PowerDomain;
use fedzero::fl::MockBackend;
use fedzero::metrics::MetricsLog;
use fedzero::scenario::campaign::{run_campaign, CampaignSpec};
use fedzero::selection::fedzero::{FedZero, SolverKind};
use fedzero::selection::semisync::SemiSync;
use fedzero::sim::{ChaosSpec, SimConfig, Simulation};
use fedzero::trace::forecast::{ErrorLevel, SeriesForecaster};
use fedzero::util::bench::fmt_ns;
use fedzero::util::json::Json;
use fedzero::util::obs;

/// Constant-power mock fixture (same shape as the endtoend bench).
fn sim_parts(
    n_clients: usize,
    n_domains: usize,
    power_w: f64,
    horizon: usize,
) -> (Vec<ClientInfo>, Vec<PowerDomain>, Vec<Vec<f64>>, Vec<SeriesForecaster>) {
    let clients: Vec<ClientInfo> = (0..n_clients)
        .map(|i| {
            let p = ClientProfile::new(
                DeviceType::ALL[i % 3],
                ModelKind::Vision,
                10,
                1.0,
            );
            ClientInfo::new(i, i % n_domains, p, (0..60).collect(), 10)
        })
        .collect();
    let domains: Vec<PowerDomain> = (0..n_domains)
        .map(|i| {
            let series = vec![power_w; horizon];
            let fc = SeriesForecaster::realistic(series.clone(), i as u64, 60.0);
            PowerDomain::new(i, "d", 800.0, series, fc, 1.0)
        })
        .collect();
    let load: Vec<Vec<f64>> = (0..n_clients).map(|_| vec![0.0; horizon]).collect();
    let load_fc: Vec<SeriesForecaster> = clients
        .iter()
        .map(|c| {
            SeriesForecaster::realistic(vec![c.capacity(); horizon], 7, 60.0)
        })
        .collect();
    (clients, domains, load, load_fc)
}

/// One FSM run over the fixture (SemiSync deadline so injected delays
/// have a deadline to miss). Returns (metrics, train steps, ns/step).
fn chaos_run(chaos: Option<ChaosSpec>, horizon: usize) -> (MetricsLog, u64, f64) {
    let n_clients = 24;
    let (clients, domains, load, load_fc) = sim_parts(n_clients, 6, 800.0, horizon);
    let backend = MockBackend::new(n_clients, 2_048, 0.2, 7);
    let mut strat = SemiSync::new(FedZero::new(SolverKind::Greedy), 15);
    let cfg = SimConfig {
        horizon,
        n_per_round: 6,
        d_max: 30,
        eval_every: 50,
        seed: 5,
        step_minutes: 1.0,
    };
    let mut sim = Simulation::new(
        cfg,
        clients,
        domains,
        load,
        load_fc,
        ErrorLevel::Realistic,
        &backend,
        &mut strat,
    );
    sim.chaos = chaos;
    let t0 = Instant::now();
    sim.run().unwrap();
    let ns = t0.elapsed().as_nanos() as f64 / horizon as f64;
    let steps = sim.steps_executed();
    (sim.metrics, steps, ns)
}

/// 2-cell campaign (calm + faulty twin) for the worker-count gate.
fn campaign_spec(chaos: ChaosSpec) -> CampaignSpec {
    let mut spec = CampaignSpec::smoke();
    spec.name = "chaos-bench".into();
    spec.strategies = vec![StrategyKind::FedZero];
    spec.chaos_axis = vec![None, Some(chaos)];
    spec
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mode = if quick { "quick" } else { "default" };
    println!("== chaos benches [{mode}] ==");
    // telemetry on for the whole bench: the determinism gates below
    // double as proof that enabling the probes changes no output, and
    // the snapshot feeds the fault-counter / phase-percentile columns
    obs::set_enabled(true);
    obs::reset();
    let horizon = if quick { 400 } else { 1_200 };

    // aggressive schedule: every submission delayed past the 15-min
    // deadline often enough that stale fencing MUST fire
    let chaos = ChaosSpec {
        dropout_per_round: 0.2,
        stale_prob: 1.0,
        mean_delay_min: 40.0,
        ..ChaosSpec::default()
    };

    // --- seeded-chaos determinism + fault visibility -------------------
    let (m_clean, steps_clean, ns_clean) = chaos_run(None, horizon);
    let (m_a, steps_a, ns_chaos) = chaos_run(Some(chaos), horizon);
    let (m_b, steps_b, _) = chaos_run(Some(chaos), horizon);
    let det_mismatch = (m_a != m_b
        || steps_a != steps_b
        || m_a.to_json().to_string_pretty() != m_b.to_json().to_string_pretty())
        as usize;
    if det_mismatch > 0 {
        eprintln!("CHAOS DETERMINISM FAILED: two identically seeded runs differ");
    } else {
        println!(
            "chaos determinism: ok (two seeded runs bit-identical, {} rounds)",
            m_a.rounds.len()
        );
    }
    let mut vis_failures = 0usize;
    for (ok, what) in [
        (m_a.rejected_updates > 0, "no stale update was fenced"),
        (m_a.timeout_rounds() > 0, "no round was closed by its deadline"),
        (m_a.total_wasted_kwh() > 0.0, "stragglers metered no waste"),
        (m_a.rejected_decisions == 0, "faults corrupted the validation path"),
        (m_clean.rejected_updates == 0, "clean run fenced an update"),
    ] {
        if !ok {
            eprintln!("FAULT VISIBILITY FAILED: {what}");
            vis_failures += 1;
        }
    }
    if vis_failures == 0 {
        println!(
            "fault visibility: ok ({} stale updates fenced, {} deadline rounds)",
            m_a.rejected_updates,
            m_a.timeout_rounds()
        );
    }
    println!(
        "chaos_step/24c_6p injector off {:>12} per step ({} rounds, {steps_clean} steps)",
        fmt_ns(ns_clean),
        m_clean.rounds.len()
    );
    println!(
        "chaos_step/24c_6p injector on  {:>12} per step ({} rounds, {steps_a} steps)",
        fmt_ns(ns_chaos),
        m_a.rounds.len()
    );

    // --- campaign worker-count determinism under faults -----------------
    let spec = campaign_spec(chaos);
    let reference = run_campaign(&spec, 1).expect("serial chaos campaign failed");
    let ref_text = reference.report_json().to_string_pretty();
    let mut worker_divergence = 0usize;
    for workers in [2usize, 8] {
        let run = run_campaign(&spec, workers).expect("parallel chaos campaign failed");
        if run.report_json().to_string_pretty() != ref_text {
            eprintln!("CHAOS CAMPAIGN DIVERGENCE at {workers} workers");
            worker_divergence += 1;
        }
    }
    let parsed = Json::parse(&ref_text).expect("chaos report does not re-parse");
    let cells = parsed.get("cells").and_then(|v| v.as_arr()).expect("no cells");
    let mut schema_failures = 0usize;
    for (i, c) in cells.iter().enumerate() {
        for key in ["chaos", "rejected_updates", "timeout_rounds"] {
            if c.get(key).is_none() {
                eprintln!("CHAOS SCHEMA FAILED: cell {i} missing key {key:?}");
                schema_failures += 1;
            }
        }
    }
    if worker_divergence == 0 && schema_failures == 0 {
        println!(
            "chaos campaign: ok ({} cells byte-identical at 1/2/8 workers)",
            cells.len()
        );
    }

    // --- machine-readable results --------------------------------------
    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("chaos".into()));
    root.insert("mode".into(), Json::Str(mode.into()));
    root.insert("ns_per_step_clean".into(), Json::Num(ns_clean));
    root.insert("ns_per_step_chaos".into(), Json::Num(ns_chaos));
    root.insert("rounds_clean".into(), Json::Num(m_clean.rounds.len() as f64));
    root.insert("rounds_chaos".into(), Json::Num(m_a.rounds.len() as f64));
    root.insert(
        "rejected_updates".into(),
        Json::Num(m_a.rejected_updates as f64),
    );
    root.insert(
        "timeout_rounds".into(),
        Json::Num(m_a.timeout_rounds() as f64),
    );
    // obs-layer view of the same runs: injected-fault counters and the
    // round-phase latency percentiles from the log2 histograms
    let s = obs::snapshot();
    root.insert(
        "round_p50_ns".into(),
        Json::Num(s.hist_percentile(obs::Hist::RoundNs, 50.0)),
    );
    root.insert(
        "round_p99_ns".into(),
        Json::Num(s.hist_percentile(obs::Hist::RoundNs, 99.0)),
    );
    for (key, c) in [
        ("obs_dropouts", obs::Ctr::ChaosDropouts),
        ("obs_delays", obs::Ctr::ChaosDelays),
        ("obs_slowdowns", obs::Ctr::ChaosSlowdowns),
        ("obs_stale_rejected", obs::Ctr::ChaosStaleRejected),
    ] {
        root.insert(key.into(), Json::Num(s.ctr(c) as f64));
    }
    root.insert("determinism_mismatch".into(), Json::Num(det_mismatch as f64));
    root.insert(
        "visibility_failures".into(),
        Json::Num(vis_failures as f64),
    );
    root.insert(
        "campaign_divergence".into(),
        Json::Num(worker_divergence as f64),
    );
    root.insert("schema_failures".into(), Json::Num(schema_failures as f64));
    let out = Json::Obj(root).to_string_pretty();
    let path = "BENCH_chaos.json";
    match fedzero::util::fsx::write_atomic(std::path::Path::new(path), out.as_bytes()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    if det_mismatch + vis_failures + worker_divergence + schema_failures > 0 {
        eprintln!("chaos gates FAILED");
        std::process::exit(1);
    }
    println!("== done ==");
}
