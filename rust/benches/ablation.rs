//! Ablation benches for the design choices DESIGN.md calls out:
//!
//!  * blocklist α — fairness (between-domain participation std) vs
//!    training throughput (§4.4: "high α ... can extend training time but
//!    ensures fair participation");
//!  * over-selection factor for the Random baseline — rounds vs wasted
//!    energy (§3.1's critique of 1.3n over-selection);
//!  * greedy vs exact branch-and-bound selection — objective gap & cost
//!    (our Gurobi substitution, DESIGN.md §2);
//!  * semi-synchronous deadline (§7 extension) — rounds vs discarded work.
//!
//! Mock backend: measures L3 scheduling behaviour, no artifacts needed.

use std::time::Instant;

use fedzero::config::Scenario;
use fedzero::coordinator::{build_dataset, ExperimentSpec};
use fedzero::config::{build, ScenarioConfig};
use fedzero::client::ModelKind;
use fedzero::fl::MockBackend;
use fedzero::selection::baselines::Baseline;
use fedzero::selection::fedzero::{FedZero, SolverKind};
use fedzero::selection::semisync::SemiSync;
use fedzero::selection::Strategy;
use fedzero::sim::{SimConfig, Simulation};
use fedzero::solver::mip::{branch_and_bound, greedy, SelClient, SelInstance};
use fedzero::trace::forecast::ErrorLevel;
use fedzero::util::rng::Rng;

fn run_mock(strategy: &mut dyn Strategy, seed: u64) -> (usize, f64, f64, Vec<usize>, Vec<usize>) {
    let spec = ExperimentSpec {
        preset: "tiny".into(),
        scenario: Scenario::Global,
        days: 2,
        n_clients: 40,
        n_per_round: 6,
        seed,
        dataset_scale: 0.2,
        use_mock: true,
        ..Default::default()
    };
    let (_, partition) = build_dataset(&spec, 16);
    let scfg = ScenarioConfig {
        scenario: spec.scenario,
        n_clients: spec.n_clients,
        days: spec.days,
        seed: spec.seed,
        ..Default::default()
    };
    let built = build(&scfg, ModelKind::Vision, 10, &partition);
    let backend = MockBackend::new(spec.n_clients, 16, 0.3, seed);
    let sim_cfg = SimConfig {
        horizon: built.horizon,
        n_per_round: spec.n_per_round,
        d_max: 60,
        eval_every: 10,
        seed,
        step_minutes: 1.0,
    };
    let domains = built.client_domains();
    let mut sim = Simulation::new(
        sim_cfg,
        built.clients,
        built.domains,
        built.load_actual,
        built.load_fc,
        ErrorLevel::Realistic,
        &backend,
        strategy,
    );
    sim.run().unwrap();
    let rounds = sim.metrics.rounds.len();
    let kwh = sim.metrics.total_energy_kwh();
    let counts = sim.metrics.participation_counts(40);
    (rounds, kwh, sim.metrics.best_accuracy(), counts, domains)
}

fn between_domain_std(counts: &[usize], domains: &[usize], rounds: usize) -> f64 {
    let n_domains = domains.iter().max().map(|&d| d + 1).unwrap_or(1);
    let mut sums = vec![0.0; n_domains];
    let mut ns = vec![0usize; n_domains];
    for (c, &d) in domains.iter().enumerate() {
        sums[d] += counts[c] as f64 / rounds.max(1) as f64;
        ns[d] += 1;
    }
    let means: Vec<f64> = sums
        .iter()
        .zip(&ns)
        .map(|(s, &n)| if n > 0 { s / n as f64 } else { 0.0 })
        .collect();
    fedzero::util::stats::std(&means)
}

fn main() {
    println!("== ablations ==");

    println!("\n[A] blocklist α (fairness vs throughput)");
    println!("{:>6} {:>8} {:>10} {:>22}", "alpha", "rounds", "kWh", "between-domain std %");
    for alpha in [0.25, 1.0, 4.0] {
        let mut fz = FedZero::new(SolverKind::Greedy);
        fz.blocklist = fedzero::selection::fairness::Blocklist::new(alpha);
        let (rounds, kwh, _acc, counts, domains) = run_mock(&mut fz, 1);
        println!(
            "{alpha:>6} {rounds:>8} {kwh:>10.2} {:>21.2}%",
            between_domain_std(&counts, &domains, rounds) * 100.0
        );
    }

    println!("\n[B] over-selection factor (Random baseline)");
    println!("{:>8} {:>8} {:>10} {:>12}", "factor", "rounds", "kWh", "kWh/round");
    for factor in [1.0, 1.3, 1.6] {
        let mut b = Baseline::random();
        b.over_select = factor;
        let (rounds, kwh, _, _, _) = run_mock(&mut b, 2);
        println!(
            "{factor:>8} {rounds:>8} {kwh:>10.2} {:>12.4}",
            kwh / rounds.max(1) as f64
        );
    }

    println!("\n[C] greedy vs exact selection (objective gap, 30 candidates)");
    let mut rng = Rng::new(3);
    let inst = SelInstance {
        n: 6,
        clients: (0..30)
            .map(|_| {
                let m_min = rng.range_f64(2.0, 15.0);
                SelClient {
                    domain: rng.below(5),
                    sigma: rng.range_f64(0.1, 10.0),
                    delta: rng.range_f64(0.05, 0.5),
                    m_min,
                    m_max: m_min * 5.0,
                    spare: (0..60)
                        .map(|_| rng.range_f64(0.0, 30.0) as f32)
                        .collect(),
                }
            })
            .collect(),
        energy: (0..5)
            .map(|_| {
                (0..60).map(|_| rng.range_f64(0.0, 14.0) as f32).collect()
            })
            .collect(),
    };
    let t0 = Instant::now();
    let g = greedy(&inst, 1);
    let tg = t0.elapsed();
    let t1 = Instant::now();
    let e = branch_and_bound(&inst, 500_000);
    let te = t1.elapsed();
    println!(
        "  greedy: obj {:.1} in {:.2} ms | exact: obj {:.1} in {:.1} ms (optimal={}) | ratio {:.3}",
        g.objective,
        tg.as_secs_f64() * 1e3,
        e.objective,
        te.as_secs_f64() * 1e3,
        e.optimal,
        g.objective / e.objective.max(1e-9),
    );

    println!("\n[D] semi-sync deadline (§7 extension, FedZero inner)");
    println!("{:>10} {:>8} {:>10} {:>10}", "deadline", "rounds", "kWh", "best acc");
    for deadline in [10usize, 30, 60] {
        let mut s = SemiSync::new(FedZero::new(SolverKind::Greedy), deadline);
        let (rounds, kwh, acc, _, _) = run_mock(&mut s, 4);
        println!("{deadline:>10} {rounds:>8} {kwh:>10.2} {acc:>10.3}");
    }
    println!("== done ==");
}
