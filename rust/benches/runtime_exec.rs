//! PJRT hot-path benches: per-entry-point execution latency of the AOT
//! artifacts — the compute cost underlying every simulated batch
//! (Table 2's samples/minute are *simulated* speeds; this is the real
//! testbed cost that bounds experiment wallclock).
//!
//! Requires `make artifacts`; skips gracefully if artifacts are missing.

use fedzero::runtime::ModelRuntime;
use fedzero::util::bench::{bench, quick, Config};
use fedzero::util::rng::Rng;

fn bench_preset(preset: &str) -> anyhow::Result<()> {
    let rt = ModelRuntime::load(std::path::Path::new("artifacts"), preset)?;
    let p = rt.param_count();
    let b = rt.batch_size();
    let d = rt.manifest.input_dim;
    let k = rt.manifest.agg_k;
    println!("-- preset {preset}: P={p} B={b} D={d} --");

    let params = rt.init_params(1)?;
    let mut rng = Rng::new(2);
    let x: Vec<f32> = (0..b * d).map(|_| rng.normal() as f32).collect();
    let y: Vec<i32> = (0..b)
        .map(|_| rng.below(rt.manifest.num_classes) as i32)
        .collect();

    let cfg = Config::default();
    bench(&format!("train_step/{preset}"), cfg, || {
        rt.train_step(&params, &params, &x, &y, 0.05, 0.01).unwrap()
    });
    bench(&format!("eval_step/{preset}"), cfg, || {
        rt.eval_step(&params, &x, &y).unwrap()
    });
    let updates: Vec<Vec<f32>> = (0..k.min(10)).map(|_| params.clone()).collect();
    let update_refs: Vec<&[f32]> = updates.iter().map(|u| u.as_slice()).collect();
    let weights = vec![1.0f32; updates.len()];
    bench(&format!("aggregate/{preset}_k{}", updates.len()), quick(), || {
        rt.aggregate(&update_refs, &weights).unwrap()
    });
    bench(&format!("init/{preset}"), quick(), || rt.init_params(3).unwrap());
    Ok(())
}

fn main() {
    println!("== runtime exec benches ==");
    for preset in ["tiny", "vision"] {
        if let Err(e) = bench_preset(preset) {
            eprintln!("skipping {preset}: {e:#} (run `make artifacts`)");
        }
    }
    println!("== done ==");
}
