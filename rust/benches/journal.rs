//! Durable-coordinator (write-ahead journal) benches + CI gates.
//!
//! Gates two recovery properties of the crash-tolerant coordinator:
//!
//! 1. **crash-resume bit-identity** — a run killed by a certain chaos
//!    crash fault and resumed from its journal + latest snapshot
//!    finishes with `MetricsLog`, step totals AND journal bytes
//!    identical to an uninterrupted run, across seeds (the resumed
//!    journal re-appends exactly the suffix the crash destroyed);
//! 2. **campaign-resume byte-identity** — a chaos campaign (crash
//!    faults on an axis) resumed over per-cell completion records
//!    produces a report byte-identical to a fresh single-pass run at
//!    1, 2 and 8 workers, including after a record file is deleted.
//!
//! Plus throughput: ns per journal append (length-prefixed, checksummed,
//! eagerly flushed frames) and recovery cost — open + torn-tail scan +
//! `verify_replay` — on the real journal the gate runs produce.
//!
//! Results go to rust/BENCH_journal.json; any gate failure exits
//! non-zero (wired into ci.sh --quick beside the chaos gates).
//!
//! Flags: --quick  CI smoke (short horizon, one seed)

use std::collections::BTreeMap;
use std::path::{Path, PathBuf};
use std::time::Instant;

use fedzero::client::{ClientInfo, ClientProfile, DeviceType, ModelKind};
use fedzero::coordinator::events::ClientEvent;
use fedzero::coordinator::journal::{verify_replay, Journal, JournalRecord};
use fedzero::coordinator::StrategyKind;
use fedzero::energy::PowerDomain;
use fedzero::fl::MockBackend;
use fedzero::metrics::MetricsLog;
use fedzero::scenario::campaign::{run_campaign, run_campaign_durable, CampaignSpec};
use fedzero::selection::fedzero::{FedZero, SolverKind};
use fedzero::selection::semisync::SemiSync;
use fedzero::sim::{ChaosSpec, CrashFault, DurableConfig, SimConfig, Simulation};
use fedzero::trace::forecast::{ErrorLevel, SeriesForecaster};
use fedzero::util::bench::fmt_ns;
use fedzero::util::json::Json;
use fedzero::util::stats;

fn scratch(name: &str) -> PathBuf {
    let dir = std::env::temp_dir().join(format!(
        "fedzero_bench_journal_{}_{name}",
        std::process::id()
    ));
    let _ = std::fs::remove_dir_all(&dir);
    std::fs::create_dir_all(&dir).unwrap();
    dir
}

/// Constant-power mock fixture (same shape as the chaos bench).
fn sim_parts(
    n_clients: usize,
    n_domains: usize,
    power_w: f64,
    horizon: usize,
) -> (Vec<ClientInfo>, Vec<PowerDomain>, Vec<Vec<f64>>, Vec<SeriesForecaster>) {
    let clients: Vec<ClientInfo> = (0..n_clients)
        .map(|i| {
            let p = ClientProfile::new(
                DeviceType::ALL[i % 3],
                ModelKind::Vision,
                10,
                1.0,
            );
            ClientInfo::new(i, i % n_domains, p, (0..60).collect(), 10)
        })
        .collect();
    let domains: Vec<PowerDomain> = (0..n_domains)
        .map(|i| {
            let series = vec![power_w; horizon];
            let fc = SeriesForecaster::realistic(series.clone(), i as u64, 60.0);
            PowerDomain::new(i, "d", 800.0, series, fc, 1.0)
        })
        .collect();
    let load: Vec<Vec<f64>> = (0..n_clients).map(|_| vec![0.0; horizon]).collect();
    let load_fc: Vec<SeriesForecaster> = clients
        .iter()
        .map(|c| {
            SeriesForecaster::realistic(vec![c.capacity(); horizon], 7, 60.0)
        })
        .collect();
    (clients, domains, load, load_fc)
}

/// One durable FSM run over the fixture (SemiSync deadline so injected
/// delays have a deadline to miss — same strategy as the chaos bench).
/// `resume` continues from the journal in `dir` instead of starting
/// fresh. The snapshot cadence must match between the original and the
/// resumed run (it shapes the journal bytes).
fn durable_run(
    seed: u64,
    chaos: ChaosSpec,
    dir: &Path,
    resume: bool,
    horizon: usize,
) -> anyhow::Result<(MetricsLog, u64)> {
    let n_clients = 24;
    let (clients, domains, load, load_fc) = sim_parts(n_clients, 6, 800.0, horizon);
    let backend = MockBackend::new(n_clients, 2_048, 0.2, 7);
    let mut strat = SemiSync::new(FedZero::new(SolverKind::Greedy), 15);
    let cfg = SimConfig {
        horizon,
        n_per_round: 6,
        d_max: 30,
        eval_every: 50,
        seed,
        step_minutes: 1.0,
    };
    let mut sim = Simulation::new(
        cfg,
        clients,
        domains,
        load,
        load_fc,
        ErrorLevel::Realistic,
        &backend,
        &mut strat,
    );
    sim.chaos = Some(chaos);
    sim.durable = Some(DurableConfig {
        dir: dir.to_path_buf(),
        snapshot_every: 5,
    });
    if resume {
        sim.resume_from(dir)?;
    } else {
        sim.run()?;
    }
    let steps = sim.steps_executed();
    Ok((std::mem::take(&mut sim.metrics), steps))
}

fn main() {
    let quick = std::env::args().any(|a| a == "--quick");
    let mode = if quick { "quick" } else { "default" };
    println!("== journal benches [{mode}] ==");
    let horizon = if quick { 400 } else { 1_200 };
    let seeds: &[u64] = if quick { &[1] } else { &[1, 5] };

    // the same fault mix as the chaos bench, with/without a certain crash
    let chaos_calm = ChaosSpec {
        dropout_per_round: 0.2,
        stale_prob: 0.5,
        mean_delay_min: 40.0,
        ..ChaosSpec::default()
    };
    let chaos_crash = ChaosSpec { crash_prob: 1.0, ..chaos_calm };

    // --- crash-resume bit-identity across seeds ------------------------
    let mut resume_mismatch = 0usize;
    let mut crash_missing = 0usize;
    let mut recovery_ms = 0.0f64;
    let mut journal_records = 0usize;
    let mut journal_bytes = 0u64;
    let mut closed_rounds = 0usize;
    for &seed in seeds {
        let dir_a = scratch(&format!("ref_{seed}"));
        let dir_b = scratch(&format!("crash_{seed}"));
        let (m_ref, steps_ref) = durable_run(seed, chaos_calm, &dir_a, false, horizon)
            .expect("uninterrupted durable run failed");
        match durable_run(seed, chaos_crash, &dir_b, false, horizon) {
            Err(e) if e.downcast_ref::<CrashFault>().is_some() => {}
            Err(e) => panic!("crashed run died for the wrong reason: {e:#}"),
            Ok(_) => {
                eprintln!("JOURNAL GATE FAILED: certain crash did not fire (seed {seed})");
                crash_missing += 1;
            }
        }
        let (m_res, steps_res) = durable_run(seed, chaos_crash, &dir_b, true, horizon)
            .expect("resume from crashed run failed");
        let wal_a = std::fs::read(dir_a.join("journal.wal")).unwrap();
        let wal_b = std::fs::read(dir_b.join("journal.wal")).unwrap();
        if m_ref != m_res
            || steps_ref != steps_res
            || m_ref.to_json().to_string_pretty() != m_res.to_json().to_string_pretty()
            || wal_a != wal_b
        {
            eprintln!(
                "JOURNAL GATE FAILED: resume diverged from the uninterrupted run (seed {seed})"
            );
            resume_mismatch += 1;
        }
        // recovery cost on the real journal: open (torn-tail scan) + replay
        let t0 = Instant::now();
        let (wal, records) = Journal::open(&dir_a.join("journal.wal"))
            .expect("reopening the reference journal failed");
        closed_rounds = verify_replay(&records).expect("reference journal does not replay");
        recovery_ms = t0.elapsed().as_secs_f64() * 1e3;
        journal_records = records.len();
        journal_bytes = wal.len_bytes();
        drop(wal);
        let _ = std::fs::remove_dir_all(&dir_a);
        let _ = std::fs::remove_dir_all(&dir_b);
    }
    if resume_mismatch + crash_missing == 0 {
        println!(
            "crash-resume: ok ({} seeds bit-identical, {closed_rounds} closed rounds replayed)",
            seeds.len()
        );
    }
    println!(
        "journal_recover/{journal_records}rec {recovery_ms:>9.2} ms ({journal_bytes} bytes)"
    );

    // --- append throughput ---------------------------------------------
    let adir = scratch("append");
    let mut wal = Journal::create(&adir.join("journal.wal")).unwrap();
    let appends = if quick { 2_000usize } else { 20_000 };
    let mut append_samples = Vec::with_capacity(appends);
    let t0 = Instant::now();
    for i in 0..appends {
        let ta = Instant::now();
        wal.append(&JournalRecord::Event {
            at: i,
            ev: ClientEvent::UpdateSubmitted { client: i % 24, epoch: 7 },
        })
        .unwrap();
        append_samples.push(ta.elapsed().as_nanos() as f64);
    }
    let ns_append = t0.elapsed().as_nanos() as f64 / appends as f64;
    let append_p50 = stats::percentile(&append_samples, 50.0);
    let append_p95 = stats::percentile(&append_samples, 95.0);
    let append_p99 = stats::percentile(&append_samples, 99.0);
    println!(
        "journal_append/{appends}rec {:>12} per record  p50 {:>12}  p99 {:>12} ({} bytes)",
        fmt_ns(ns_append),
        fmt_ns(append_p50),
        fmt_ns(append_p99),
        wal.len_bytes()
    );
    drop(wal);
    let _ = std::fs::remove_dir_all(&adir);

    // --- campaign-resume byte-identity at 1/2/8 workers -----------------
    let mut spec = CampaignSpec::smoke();
    spec.name = "journal-bench".into();
    spec.strategies = vec![StrategyKind::FedZero];
    spec.chaos_axis = vec![None, Some(chaos_crash)];
    let reference = run_campaign(&spec, 1).expect("serial campaign failed");
    let ref_text = reference.report_json().to_string_pretty();
    let cdir = scratch("campaign");
    let mut campaign_divergence = 0usize;
    for (i, &workers) in [1usize, 2, 8].iter().enumerate() {
        if i == 1 {
            // a lost record must be recomputed, not break the report
            let _ = std::fs::remove_file(cdir.join("cells").join("cell_0.json"));
        }
        let run = run_campaign_durable(&spec, workers, &cdir)
            .expect("durable campaign failed");
        if run.report_json().to_string_pretty() != ref_text {
            eprintln!("JOURNAL GATE FAILED: durable campaign diverged at {workers} workers");
            campaign_divergence += 1;
        }
    }
    if campaign_divergence == 0 {
        println!(
            "campaign resume: ok ({} cells byte-identical at 1/2/8 workers)",
            reference.results.len()
        );
    }
    let _ = std::fs::remove_dir_all(&cdir);

    // --- machine-readable results --------------------------------------
    let mut root = BTreeMap::new();
    root.insert("bench".into(), Json::Str("journal".into()));
    root.insert("mode".into(), Json::Str(mode.into()));
    root.insert("ns_per_append".into(), Json::Num(ns_append));
    root.insert("append_p50_ns".into(), Json::Num(append_p50));
    root.insert("append_p95_ns".into(), Json::Num(append_p95));
    root.insert("append_p99_ns".into(), Json::Num(append_p99));
    root.insert("recovery_ms".into(), Json::Num(recovery_ms));
    root.insert("journal_records".into(), Json::Num(journal_records as f64));
    root.insert("journal_bytes".into(), Json::Num(journal_bytes as f64));
    root.insert("closed_rounds".into(), Json::Num(closed_rounds as f64));
    root.insert("resume_mismatch".into(), Json::Num(resume_mismatch as f64));
    root.insert("crash_missing".into(), Json::Num(crash_missing as f64));
    root.insert(
        "campaign_divergence".into(),
        Json::Num(campaign_divergence as f64),
    );
    let out = Json::Obj(root).to_string_pretty();
    let path = "BENCH_journal.json";
    match fedzero::util::fsx::write_atomic(std::path::Path::new(path), out.as_bytes()) {
        Ok(()) => println!("wrote {path}"),
        Err(e) => eprintln!("could not write {path}: {e}"),
    }

    if resume_mismatch + crash_missing + campaign_divergence > 0 {
        eprintln!("journal gates FAILED");
        std::process::exit(1);
    }
    println!("== done ==");
}
