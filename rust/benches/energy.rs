//! Energy-subsystem benches: the runtime power-attribution loop (§4.5) —
//! executed once per domain per timestep inside every round — and the
//! trace generators.

use fedzero::energy::{attribute_power, waterfill, PowerRequest};
use fedzero::trace::load::LoadModel;
use fedzero::trace::solar;
use fedzero::util::bench::{bench, Config};
use fedzero::util::rng::Rng;

fn requests(n: usize, seed: u64) -> Vec<PowerRequest> {
    let mut rng = Rng::new(seed);
    (0..n)
        .map(|_| {
            let min = rng.range_f64(0.0, 5.0);
            PowerRequest {
                need_min_wh: min,
                need_max_wh: min + rng.range_f64(0.0, 10.0),
                usable_wh: rng.range_f64(0.0, 12.0),
            }
        })
        .collect()
}

fn main() {
    let cfg = Config::default();
    println!("== energy benches ==");

    for n in [2usize, 5, 10, 50] {
        let reqs = requests(n, n as u64);
        bench(&format!("attribute_power/{n}_clients"), cfg, || {
            attribute_power(10.0, &reqs)
        });
    }

    let w: Vec<f64> = (0..20).map(|i| 1.0 + i as f64).collect();
    let caps: Vec<f64> = (0..20).map(|i| 2.0 + (i % 5) as f64).collect();
    bench("waterfill/20_clients", cfg, || waterfill(25.0, &w, &caps));

    // trace generation (scenario build cost)
    let site = &solar::global_sites()[0];
    bench("solar_trace/7d_1min", cfg, || {
        let mut rng = Rng::new(9);
        solar::generate(site, 800.0, 160, 7 * 1440, 1.0, &mut rng, None)
    });
    bench("load_trace/7d_1min", cfg, || {
        let mut rng = Rng::new(10);
        let m = LoadModel::sample(&mut rng, 0.0);
        m.generate(7 * 1440, 1.0, &mut rng)
    });
    println!("== done ==");
}
