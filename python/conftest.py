"""Make `import compile` work regardless of pytest's invocation directory
(the Makefile runs `cd python && pytest tests/`; the top-level check runs
`pytest python/tests/` from the repo root)."""
import os
import sys

sys.path.insert(0, os.path.dirname(__file__))
