"""L1 performance model: VMEM footprint + MXU utilization estimates.

Pallas runs under interpret=True on this CPU testbed, so wallclock is not a
TPU proxy (see DESIGN.md §Hardware-Adaptation). What we CAN reason about is
the *structure* the BlockSpecs imply on real hardware:

  * VMEM residency  — per grid step the matmul kernel holds an (bm, bk)
    x-tile, a (bk, bn) w-tile and the (bm, bn) accumulator; all three must
    fit VMEM (~16 MiB/core on TPUv4) with room for double buffering.
  * MXU utilization — the systolic array is 128×128; tiles below that
    leave lanes idle. We report the tile-shape efficiency
    (bm/128̂ · bn/128̂ · bk/128̂ with each factor capped at 1) and the
    arithmetic intensity (FLOPs per HBM byte), which decides whether the
    kernel is compute- or bandwidth-bound relative to the ~275 FLOP/B
    ridge of a TPUv4.

Usage:  python -m compile.perf_analysis [--presets tiny,vision,...]
Also importable by tests.
"""

import argparse
from dataclasses import dataclass

from . import model as M
from .kernels.matmul import _pick_block, _DEFAULT_BLOCK

VMEM_BYTES = 16 * 1024 * 1024  # TPUv4 per-core VMEM
MXU_EDGE = 128
F32 = 4
# TPUv4: ~275 bf16 TFLOP/s vs ~1.2 TB/s HBM -> ridge ~229 FLOP/B (bf16);
# f32 through the MXU is ~4x slower, ridge ~57
RIDGE_F32 = 57.0


@dataclass
class MatmulReport:
    name: str
    m: int
    n: int
    k: int
    bm: int
    bn: int
    bk: int
    vmem_bytes: int
    vmem_frac: float
    mxu_tile_eff: float
    arithmetic_intensity: float
    compute_bound: bool

    def row(self) -> str:
        return (
            f"{self.name:<28} {self.m:>5}x{self.k:<5}@{self.k:>5}x{self.n:<5} "
            f"tiles {self.bm:>3}x{self.bn:<3}x{self.bk:<3} "
            f"VMEM {self.vmem_bytes/1024:>7.1f} KiB ({self.vmem_frac*100:>5.2f}%) "
            f"MXU-tile {self.mxu_tile_eff*100:>5.1f}%  AI {self.arithmetic_intensity:>6.1f} "
            f"[{'compute' if self.compute_bound else 'bandwidth'}-bound]"
        )


def analyze_matmul(name, m, k, n, dtype_bytes=F32):
    """Report for one tiled matmul as scheduled by kernels.matmul."""
    bm = _pick_block(m, _DEFAULT_BLOCK)
    bn = _pick_block(n, _DEFAULT_BLOCK)
    bk = _pick_block(k, _DEFAULT_BLOCK)
    # resident tiles: x, w, accumulator (+ bias tile, negligible)
    vmem = (bm * bk + bk * bn + bm * bn) * dtype_bytes
    # double buffering of the two input tiles
    vmem_db = vmem + (bm * bk + bk * bn) * dtype_bytes
    tile_eff = (
        min(bm, MXU_EDGE)
        / MXU_EDGE
        * min(bn, MXU_EDGE)
        / MXU_EDGE
        * min(bk, MXU_EDGE)
        / MXU_EDGE
    )
    # per-kernel totals: 2mnk FLOPs; HBM traffic with this schedule:
    # x read n/bn times, w read m/bm times, out written once
    flops = 2.0 * m * n * k
    traffic = (
        m * k * (n // bn) + k * n * (m // bm) + m * n
    ) * dtype_bytes
    ai = flops / traffic
    return MatmulReport(
        name=name,
        m=m,
        n=n,
        k=k,
        bm=bm,
        bn=bn,
        bk=bk,
        vmem_bytes=vmem_db,
        vmem_frac=vmem_db / VMEM_BYTES,
        mxu_tile_eff=tile_eff,
        arithmetic_intensity=ai,
        compute_bound=ai >= RIDGE_F32,
    )


def preset_reports(cfg: M.ModelConfig):
    """All matmuls in one train step (fwd + bwd of each dense layer)."""
    reports = []
    b = cfg.batch_size
    for li, (d_in, d_out) in enumerate(cfg.layer_dims):
        reports.append(analyze_matmul(f"layer{li}/fwd", b, d_in, d_out))
        reports.append(analyze_matmul(f"layer{li}/bwd_dx", b, d_out, d_in))
        reports.append(analyze_matmul(f"layer{li}/bwd_dw", d_in, b, d_out))
    return reports


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--presets", default="tiny,vision,seq,speech")
    args = ap.parse_args()
    for name in args.presets.split(","):
        cfg = M.PRESETS[name]
        print(f"\n== preset {name} (P={cfg.param_count}) ==")
        worst_vmem = 0.0
        for r in preset_reports(cfg):
            print("  " + r.row())
            worst_vmem = max(worst_vmem, r.vmem_frac)
        print(
            f"  -> peak VMEM {worst_vmem*100:.2f}% of 16 MiB; all tiles "
            f"double-buffer comfortably"
        )
        # elementwise kernels: streaming, VPU-bound by construction
        print(
            f"  fedprox_step: 4 streams x {cfg.param_count} f32 "
            f"({4*cfg.param_count*4/1024:.0f} KiB/step), tile 8192 -> pure "
            f"bandwidth, no reuse to exploit"
        )


if __name__ == "__main__":
    main()
