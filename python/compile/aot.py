"""AOT compile path: lower the L2 model functions to HLO *text* artifacts.

This is the only place Python runs; the Rust coordinator loads the emitted
``artifacts/*.hlo.txt`` via the `xla` crate's PJRT CPU client and never
touches Python again.

Interchange format is HLO text, NOT a serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which xla_extension 0.5.1 rejects
(`proto.id() <= INT_MAX`); the text parser reassigns ids and round-trips
cleanly (see /opt/xla-example/README.md).

Usage:
  python -m compile.aot --out-dir ../artifacts [--presets tiny,vision,...]

Emits, per preset:
  <preset>_init.hlo.txt        (seed i32[1]) -> (params f32[P])
  <preset>_train_step.hlo.txt  (params, global, x, y, lr, mu) -> (params', loss, correct)
  <preset>_eval_step.hlo.txt   (params, x, y) -> (loss_sum, correct)
  <preset>_aggregate.hlo.txt   (updates f32[K,P], weights f32[K]) -> (params f32[P])
  <preset>_manifest.json       shapes + metadata consumed by rust/src/runtime
"""

import argparse
import json
import os
from functools import partial

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as M


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (return_tuple=True)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype=jnp.float32):
    return jax.ShapeDtypeStruct(shape, dtype)


def lower_preset(cfg: M.ModelConfig):
    """Lower all four entry points for one model preset. Returns
    {artifact_name: hlo_text} plus the manifest dict."""
    P = cfg.param_count
    B = cfg.batch_size
    D = cfg.input_dim
    K = cfg.agg_k

    f32, i32 = jnp.float32, jnp.int32

    def train_fn(params, glob, x, y, lr, mu):
        return M.train_step(cfg, params, glob, x, y, lr, mu)

    def eval_fn(params, x, y):
        return M.eval_step(cfg, params, x, y)

    def init_fn(seed):
        return (M.init_params(cfg, seed),)

    def agg_fn(updates, weights):
        return (M.aggregate(cfg, updates, weights),)

    lowerings = {
        "train_step": jax.jit(train_fn).lower(
            _spec((P,)), _spec((P,)), _spec((B, D)), _spec((B,), i32),
            _spec((1,)), _spec((1,)),
        ),
        "eval_step": jax.jit(eval_fn).lower(
            _spec((P,)), _spec((B, D)), _spec((B,), i32),
        ),
        "init": jax.jit(init_fn).lower(_spec((1,), i32)),
        "aggregate": jax.jit(agg_fn).lower(
            _spec((K, P)), _spec((K,)),
        ),
    }
    texts = {name: to_hlo_text(low) for name, low in lowerings.items()}

    manifest = {
        "preset": cfg.name,
        "param_count": P,
        "input_dim": D,
        "num_classes": cfg.num_classes,
        "batch_size": B,
        "agg_k": K,
        "hidden": list(cfg.hidden),
        "artifacts": {name: f"{cfg.name}_{name}.hlo.txt" for name in texts},
        "entry_points": {
            "train_step": {
                "inputs": [["f32", [P]], ["f32", [P]], ["f32", [B, D]],
                           ["i32", [B]], ["f32", [1]], ["f32", [1]]],
                "outputs": [["f32", [P]], ["f32", [1]], ["i32", [1]]],
            },
            "eval_step": {
                "inputs": [["f32", [P]], ["f32", [B, D]], ["i32", [B]]],
                "outputs": [["f32", [1]], ["i32", [1]]],
            },
            "init": {
                "inputs": [["i32", [1]]],
                "outputs": [["f32", [P]]],
            },
            "aggregate": {
                "inputs": [["f32", [K, P]], ["f32", [K]]],
                "outputs": [["f32", [P]]],
            },
        },
    }
    return texts, manifest


def emit(out_dir: str, presets):
    os.makedirs(out_dir, exist_ok=True)
    for name in presets:
        cfg = M.PRESETS[name]
        texts, manifest = lower_preset(cfg)
        for fn_name, text in texts.items():
            path = os.path.join(out_dir, f"{cfg.name}_{fn_name}.hlo.txt")
            with open(path, "w") as f:
                f.write(text)
            print(f"wrote {path} ({len(text)} chars)")
        mpath = os.path.join(out_dir, f"{cfg.name}_manifest.json")
        with open(mpath, "w") as f:
            json.dump(manifest, f, indent=2)
        print(f"wrote {mpath} (P={manifest['param_count']})")


def main():
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument(
        "--presets", default="tiny,vision,seq,speech",
        help="comma-separated preset names (see model.PRESETS)",
    )
    args = ap.parse_args()
    emit(args.out_dir, [p for p in args.presets.split(",") if p])


if __name__ == "__main__":
    main()
