"""Layer-2: the per-client FL training computation in JAX.

An MLP classifier (the compute pattern shared by the paper's four
model/dataset pairs at reproduction scale) with:

  * forward + backward through the Pallas ``dense`` layer (custom VJP, so
    both GEMM directions run in the L1 kernel),
  * softmax cross-entropy loss,
  * FedProx-SGD local update (proximal term toward the round's global
    model, Li et al. MLSys'20 — the paper trains three of its four tasks
    with FedProx),
  * an eval step and the FedAvg weighted aggregation.

All functions operate on a single *flat* f32[P] parameter vector so the
Rust coordinator can treat model state as one buffer; (un)packing happens
inside the traced function and is free after XLA fusion.

Presets mirror the paper's four tasks at testbed scale (see DESIGN.md §2
for the substitution rationale).
"""

from dataclasses import dataclass, field
from typing import List, Tuple

import jax
import jax.numpy as jnp

from . import kernels


@dataclass(frozen=True)
class ModelConfig:
    """Static architecture + training-step configuration."""

    name: str
    input_dim: int
    hidden: Tuple[int, ...]
    num_classes: int
    batch_size: int
    agg_k: int = 16  # fixed aggregation fan-in (zero-padded)

    @property
    def layer_dims(self) -> List[Tuple[int, int]]:
        dims = (self.input_dim,) + tuple(self.hidden) + (self.num_classes,)
        return list(zip(dims[:-1], dims[1:]))

    @property
    def param_count(self) -> int:
        return sum(d_in * d_out + d_out for d_in, d_out in self.layer_dims)


# The paper's four dataset/model pairs, downscaled to synthetic tasks with
# matched statistical structure (DESIGN.md §2). `tiny` exists for tests and
# the quickstart example.
PRESETS = {
    "tiny": ModelConfig("tiny", input_dim=32, hidden=(64,), num_classes=8,
                        batch_size=16),
    "vision": ModelConfig("vision", input_dim=256, hidden=(256, 128),
                          num_classes=20, batch_size=16),  # CIFAR-100-like
    "imagenet": ModelConfig("imagenet", input_dim=384, hidden=(256, 128),
                            num_classes=40, batch_size=16),  # TinyImageNet-like
    "seq": ModelConfig("seq", input_dim=128, hidden=(256,), num_classes=32,
                       batch_size=16),  # Shakespeare-like
    "speech": ModelConfig("speech", input_dim=128, hidden=(192, 96),
                          num_classes=30, batch_size=16),  # GSC/KWT-like
}


def unpack(cfg: ModelConfig, flat):
    """Split the flat f32[P] vector into [(w, b), ...] per layer."""
    params = []
    off = 0
    for d_in, d_out in cfg.layer_dims:
        w = flat[off:off + d_in * d_out].reshape(d_in, d_out)
        off += d_in * d_out
        b = flat[off:off + d_out]
        off += d_out
        params.append((w, b))
    return params


def pack(params):
    """Inverse of :func:`unpack`."""
    leaves = []
    for w, b in params:
        leaves.append(w.reshape(-1))
        leaves.append(b)
    return jnp.concatenate(leaves)


def forward(cfg: ModelConfig, flat, x):
    """Logits for a batch. Hidden layers use fused dense+ReLU."""
    params = unpack(cfg, flat)
    h = x
    for i, (w, b) in enumerate(params):
        last = i == len(params) - 1
        h = kernels.dense(h, w, b, not last)
    return h


def _ce_loss(cfg: ModelConfig, flat, x, y):
    """Mean softmax cross-entropy (the FedProx proximal term is applied in
    the update kernel, not the loss — its gradient is mu*(p-p0))."""
    logits = forward(cfg, flat, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
    return jnp.mean(nll), logits


def train_step(cfg: ModelConfig, flat, flat_global, x, y, lr, mu):
    """One local FedProx-SGD step on a minibatch.

    Args:
      flat: local model, f32[P].
      flat_global: round-start global model, f32[P].
      x: f32[B, D] features. y: i32[B] labels.
      lr, mu: f32[1] learning rate / proximal coefficient.
    Returns:
      (new_flat f32[P], loss f32[1], correct i32[1])
    """
    (loss, logits), grad = jax.value_and_grad(
        lambda p: _ce_loss(cfg, p, x, y), has_aux=True
    )(flat)
    new_flat = kernels.fedprox_step(flat, flat_global, grad, lr[0], mu[0])
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.int32))
    return new_flat, loss.reshape(1), correct.reshape(1)


def eval_step(cfg: ModelConfig, flat, x, y):
    """Summed loss + correct count over one eval batch (server reduces)."""
    logits = forward(cfg, flat, x)
    logp = jax.nn.log_softmax(logits, axis=-1)
    nll = -jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0]
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.int32))
    return jnp.sum(nll).reshape(1), correct.reshape(1)


def init_params(cfg: ModelConfig, seed):
    """He-initialised flat parameter vector from an i32[1] seed."""
    key = jax.random.PRNGKey(seed[0])
    parts = []
    for d_in, d_out in cfg.layer_dims:
        key, wk = jax.random.split(key)
        scale = jnp.sqrt(2.0 / d_in)
        parts.append((jax.random.normal(wk, (d_in, d_out)) * scale).reshape(-1))
        parts.append(jnp.zeros((d_out,)))
    return jnp.concatenate(parts)


def aggregate(cfg: ModelConfig, updates, weights):
    """FedAvg: weighted mean of K stacked flat models (0-weight padding ok)."""
    total = kernels.weighted_sum(updates, weights)
    denom = jnp.maximum(jnp.sum(weights), 1e-12)
    return total / denom


# ---------------------------------------------------------------------------
# Pure-jnp oracles (no Pallas) used by the pytest suite to validate the full
# step, not just individual kernels.
# ---------------------------------------------------------------------------

def forward_ref(cfg: ModelConfig, flat, x):
    params = unpack(cfg, flat)
    h = x
    for i, (w, b) in enumerate(params):
        h = h @ w + b
        if i < len(params) - 1:
            h = jnp.maximum(h, 0)
    return h


def train_step_ref(cfg: ModelConfig, flat, flat_global, x, y, lr, mu):
    def loss_fn(p):
        logits = forward_ref(cfg, p, x)
        logp = jax.nn.log_softmax(logits, axis=-1)
        return jnp.mean(-jnp.take_along_axis(logp, y[:, None], axis=-1)[:, 0])

    loss, grad = jax.value_and_grad(loss_fn)(flat)
    new_flat = flat - lr[0] * (grad + mu[0] * (flat - flat_global))
    logits = forward_ref(cfg, flat, x)
    correct = jnp.sum((jnp.argmax(logits, axis=-1) == y).astype(jnp.int32))
    return new_flat, loss.reshape(1), correct.reshape(1)
