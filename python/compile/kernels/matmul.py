"""Tiled Pallas matmul with fused bias/ReLU epilogue, plus a custom-VJP
dense layer whose backward pass also runs through Pallas.

TPU mapping (see DESIGN.md §Hardware-Adaptation): the grid is (M/bm, N/bn,
K/bk); each step keeps an (bm, bk) x-tile, a (bk, bn) w-tile and the (bm, bn)
output accumulator VMEM-resident, accumulating over the K grid axis — the
MXU systolic-array schedule, not a CUDA warp port. Block sizes default to
128 (MXU native) and shrink to the largest divisor of the dimension so no
padding logic is needed at these model scales.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# MXU-native tile edge on real TPU hardware.
_DEFAULT_BLOCK = 128


def _pick_block(dim: int, target: int) -> int:
    """Largest divisor of ``dim`` that is <= target (>=1)."""
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def _matmul_kernel(x_ref, w_ref, b_ref, o_ref, *, nk: int, relu: bool,
                   has_bias: bool):
    """One (i, j, k) grid step: o[i,j] += x[i,k] @ w[k,j]; epilogue at k=nk-1."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    o_ref[...] += jnp.dot(
        x_ref[...], w_ref[...], preferred_element_type=o_ref.dtype
    )

    @pl.when(k == nk - 1)
    def _epilogue():
        acc = o_ref[...]
        if has_bias:
            acc = acc + b_ref[...]
        if relu:
            acc = jnp.maximum(acc, 0)
        o_ref[...] = acc


def matmul(x, w, bias=None, relu=False, bm=None, bn=None, bk=None):
    """``x @ w`` (+ bias) (ReLU?) as a tiled Pallas kernel.

    Args:
      x: (M, K) array.
      w: (K, N) array.
      bias: optional (N,) array fused into the final K step.
      relu: fuse a ReLU epilogue.
      bm/bn/bk: tile-size overrides (defaults: largest divisor <= 128).
    """
    m, kx = x.shape
    kw, n = w.shape
    assert kx == kw, f"contraction mismatch: {x.shape} @ {w.shape}"
    bm = _pick_block(m, bm or _DEFAULT_BLOCK)
    bn = _pick_block(n, bn or _DEFAULT_BLOCK)
    bk = _pick_block(kx, bk or _DEFAULT_BLOCK)
    grid = (m // bm, n // bn, kx // bk)

    has_bias = bias is not None
    # Pallas wants a concrete operand list; feed a dummy (1,) bias when
    # absent so the kernel signature stays fixed.
    b_arg = bias if has_bias else jnp.zeros((1,), x.dtype)
    b_spec = (
        pl.BlockSpec((bn,), lambda i, j, k: (j,))
        if has_bias
        else pl.BlockSpec((1,), lambda i, j, k: (0,))
    )

    kernel = partial(
        _matmul_kernel, nk=grid[2], relu=relu, has_bias=has_bias
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bk), lambda i, j, k: (i, k)),
            pl.BlockSpec((bk, bn), lambda i, j, k: (k, j)),
            b_spec,
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j, k: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), x.dtype),
        interpret=True,
    )(x, w, b_arg)


def _relu_grad_kernel(g_ref, y_ref, o_ref):
    o_ref[...] = g_ref[...] * (y_ref[...] > 0).astype(g_ref.dtype)


def relu_grad(g, y, bm=None, bn=None):
    """Elementwise backward mask for the fused ReLU: g * (y > 0)."""
    m, n = g.shape
    bm = _pick_block(m, bm or _DEFAULT_BLOCK)
    bn = _pick_block(n, bn or _DEFAULT_BLOCK)
    return pl.pallas_call(
        _relu_grad_kernel,
        grid=(m // bm, n // bn),
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        ],
        out_specs=pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), g.dtype),
        interpret=True,
    )(g, y)


@partial(jax.custom_vjp, nondiff_argnums=(3,))
def dense(x, w, b, relu=False):
    """Dense layer y = relu?(x @ w + b) with a Pallas forward AND backward."""
    return matmul(x, w, bias=b, relu=relu)


def _dense_fwd(x, w, b, relu):
    y = matmul(x, w, bias=b, relu=relu)
    return y, (x, w, y)


def _dense_bwd(relu, res, g):
    x, w, y = res
    if relu:
        g = relu_grad(g, y)
    # dx = g @ w^T ; dw = x^T @ g ; db = sum_rows(g). The transposes are
    # materialised by XLA; both GEMMs run through the tiled Pallas kernel.
    dx = matmul(g, w.T)
    dw = matmul(x.T, g)
    db = jnp.sum(g, axis=0)
    return dx, dw, db


dense.defvjp(_dense_fwd, _dense_bwd)
