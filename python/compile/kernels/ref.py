"""Pure-jnp reference oracle for every Pallas kernel.

These are the ground truth against which the Pallas kernels are verified
(pytest + hypothesis in ``python/tests``). Keep them boring: plain jnp ops,
no tiling, no fusion tricks.
"""

import jax.numpy as jnp


def matmul(x, w, bias=None, relu=False):
    """y = x @ w (+ bias) (relu?)."""
    y = jnp.dot(x, w, preferred_element_type=x.dtype)
    if bias is not None:
        y = y + bias
    if relu:
        y = jnp.maximum(y, 0)
    return y


def relu_grad(g, y):
    """Backward of fused ReLU: pass gradient where the activation was > 0."""
    return g * (y > 0).astype(g.dtype)


def fedprox_step(p, p0, g, lr, mu):
    """FedProx-SGD update: p <- p - lr * (g + mu * (p - p0)).

    ``p0`` is the round's global model; the proximal term pulls local
    iterates back toward it (Li et al., MLSys'20).
    """
    return p - lr * (g + mu * (p - p0))


def weighted_sum(updates, weights):
    """FedAvg numerator: sum_k weights[k] * updates[k, :].

    Normalisation by sum(weights) happens in the caller so zero-padded
    entries (weight 0) are free.
    """
    return jnp.einsum("k,kp->p", weights, updates)
