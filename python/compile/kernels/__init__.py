"""Layer-1 Pallas kernels for FedZero's training compute path.

Every kernel here runs in ``interpret=True`` mode so the lowered HLO is
executable on the CPU PJRT plugin (real Mosaic lowering would emit a TPU
custom-call). The kernels are nonetheless *structured* for TPU: MXU-shaped
tiled matmuls with VMEM-resident blocks, and 1-D VPU-style elementwise
kernels over the flat parameter vector.

Public API:
  matmul(x, w, bias=None, relu=False)      -- tiled matmul + fused epilogue
  dense(x, w, b, relu)                     -- custom-VJP dense layer (fwd+bwd in Pallas)
  relu_grad(g, y)                          -- backward mask for fused ReLU
  fedprox_step(p, p0, g, lr, mu)           -- fused FedProx-SGD parameter update
  weighted_sum(updates, weights)           -- FedAvg aggregation (K x P -> P)
"""

from .matmul import matmul, dense, relu_grad
from .elementwise import fedprox_step
from .aggregate import weighted_sum
from . import ref

__all__ = [
    "matmul",
    "dense",
    "relu_grad",
    "fedprox_step",
    "weighted_sum",
    "ref",
]
