"""1-D elementwise Pallas kernels over the flat parameter vector.

VPU-style: the flat f32[P] vector is tiled into VMEM-sized 1-D blocks; the
scalar hyper-parameters ride along as (1,)-shaped operands broadcast to every
block (the interpret-mode stand-in for SMEM scalar prefetch).
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# 8 * 128 lanes * 8 sublanes -- a comfortable VPU tile; must divide P or we
# fall back to the largest divisor.
_DEFAULT_BLOCK = 8192


def _pick_block(dim: int, target: int) -> int:
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def _fedprox_kernel(p_ref, p0_ref, g_ref, lr_ref, mu_ref, o_ref):
    lr = lr_ref[0]
    mu = mu_ref[0]
    p = p_ref[...]
    o_ref[...] = p - lr * (g_ref[...] + mu * (p - p0_ref[...]))


def fedprox_step(p, p0, g, lr, mu, block=None):
    """Fused FedProx-SGD update over the flat parameter vector.

    p <- p - lr * (g + mu * (p - p0))

    Args:
      p: flat local params, f32[P].
      p0: flat global (round-start) params, f32[P].
      g: flat gradient, f32[P].
      lr, mu: scalars (python float or 0-d/1-d arrays).
    """
    (n,) = p.shape
    b = _pick_block(n, block or _DEFAULT_BLOCK)
    lr = jnp.asarray(lr, p.dtype).reshape((1,))
    mu = jnp.asarray(mu, p.dtype).reshape((1,))
    scalar_spec = pl.BlockSpec((1,), lambda i: (0,))
    vec_spec = pl.BlockSpec((b,), lambda i: (i,))
    return pl.pallas_call(
        _fedprox_kernel,
        grid=(n // b,),
        in_specs=[vec_spec, vec_spec, vec_spec, scalar_spec, scalar_spec],
        out_specs=vec_spec,
        out_shape=jax.ShapeDtypeStruct((n,), p.dtype),
        interpret=True,
    )(p, p0, g, lr, mu)
