"""FedAvg aggregation kernel: out[P] = sum_k weights[k] * updates[k, P].

Tiled over P; each grid step holds a (K, bp) slab of client updates plus the
full (K,) weight vector in VMEM and reduces with a single matvec — on real
hardware this is one MXU pass per tile with the weights resident in SMEM.
Zero-weight rows make fixed-K padding free, which is how the Rust server
handles rounds that return fewer than K clients.
"""

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

_DEFAULT_BLOCK = 4096


def _pick_block(dim: int, target: int) -> int:
    b = min(dim, target)
    while dim % b != 0:
        b -= 1
    return b


def _weighted_sum_kernel(u_ref, w_ref, o_ref):
    # (K,) @ (K, bp) -> (bp,)
    o_ref[...] = jnp.dot(
        w_ref[...], u_ref[...], preferred_element_type=o_ref.dtype
    )


def weighted_sum(updates, weights, block=None):
    """sum_k weights[k] * updates[k, :] as a tiled Pallas kernel.

    Args:
      updates: f32[K, P] stacked client parameter vectors.
      weights: f32[K] aggregation weights (0 for padding rows).
    Returns:
      f32[P] weighted sum (un-normalised).
    """
    k, p = updates.shape
    bp = _pick_block(p, block or _DEFAULT_BLOCK)
    return pl.pallas_call(
        _weighted_sum_kernel,
        grid=(p // bp,),
        in_specs=[
            pl.BlockSpec((k, bp), lambda i: (0, i)),
            pl.BlockSpec((k,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((bp,), lambda i: (i,)),
        out_shape=jax.ShapeDtypeStruct((p,), updates.dtype),
        interpret=True,
    )(updates, weights)
