"""L2 correctness: the jitted model entry points against pure-jnp oracles,
plus learning-dynamics sanity (loss decreases on a learnable task)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import model as M


def batch(cfg, seed=0):
    key = jax.random.PRNGKey(seed)
    kx, ky = jax.random.split(key)
    x = jax.random.normal(kx, (cfg.batch_size, cfg.input_dim))
    y = jax.random.randint(ky, (cfg.batch_size,), 0, cfg.num_classes)
    return x, y


@pytest.mark.parametrize("preset", sorted(M.PRESETS))
def test_param_count_matches_packing(preset):
    cfg = M.PRESETS[preset]
    flat = M.init_params(cfg, np.array([1], np.int32))
    assert flat.shape == (cfg.param_count,)
    assert M.pack(M.unpack(cfg, flat)).shape == flat.shape
    np.testing.assert_allclose(M.pack(M.unpack(cfg, flat)), flat)


@pytest.mark.parametrize("preset", ["tiny", "vision"])
def test_forward_matches_ref(preset):
    cfg = M.PRESETS[preset]
    flat = M.init_params(cfg, np.array([2], np.int32))
    x, _ = batch(cfg)
    np.testing.assert_allclose(
        M.forward(cfg, flat, x), M.forward_ref(cfg, flat, x),
        rtol=1e-4, atol=1e-4,
    )


@settings(max_examples=8, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), lr=st.floats(1e-4, 0.5),
       mu=st.floats(0.0, 0.5))
def test_train_step_matches_ref(seed, lr, mu):
    cfg = M.PRESETS["tiny"]
    flat = M.init_params(cfg, np.array([seed % 1000], np.int32))
    glob = flat * 0.95
    x, y = batch(cfg, seed)
    lr_, mu_ = jnp.array([lr]), jnp.array([mu])
    nf, loss, corr = M.train_step(cfg, flat, glob, x, y, lr_, mu_)
    nf2, loss2, corr2 = M.train_step_ref(cfg, flat, glob, x, y, lr_, mu_)
    np.testing.assert_allclose(nf, nf2, rtol=2e-4, atol=1e-5)
    np.testing.assert_allclose(loss, loss2, rtol=1e-4)
    assert int(corr[0]) == int(corr2[0])


def test_eval_step_counts():
    cfg = M.PRESETS["tiny"]
    flat = M.init_params(cfg, np.array([3], np.int32))
    x, y = batch(cfg, 3)
    loss_sum, correct = M.eval_step(cfg, flat, x, y)
    logits = M.forward_ref(cfg, flat, x)
    expect_correct = int(jnp.sum(jnp.argmax(logits, -1) == y))
    assert int(correct[0]) == expect_correct
    assert float(loss_sum[0]) > 0


def test_init_deterministic_and_seed_sensitive():
    cfg = M.PRESETS["tiny"]
    a = M.init_params(cfg, np.array([7], np.int32))
    b = M.init_params(cfg, np.array([7], np.int32))
    c = M.init_params(cfg, np.array([8], np.int32))
    np.testing.assert_allclose(a, b)
    assert not np.allclose(a, c)


def test_aggregate_mean_identity():
    """Aggregating identical models must return that model; weighting must
    be a convex combination."""
    cfg = M.PRESETS["tiny"]
    P, K = cfg.param_count, cfg.agg_k
    flat = M.init_params(cfg, np.array([4], np.int32))
    updates = jnp.tile(flat[None, :], (K, 1))
    weights = jnp.ones(K)
    np.testing.assert_allclose(
        M.aggregate(cfg, updates, weights), flat, rtol=1e-5, atol=1e-6
    )
    # zero-padded: only first two rows count
    u2 = jnp.zeros((K, P)).at[0].set(1.0).at[1].set(3.0)
    w2 = jnp.zeros(K).at[0].set(1.0).at[1].set(1.0)
    np.testing.assert_allclose(
        M.aggregate(cfg, u2, w2), jnp.full((P,), 2.0), rtol=1e-6
    )


def test_loss_decreases_on_learnable_task():
    """A few local FedProx steps on a fixed batch must reduce the loss —
    the end-to-end signal that fwd+bwd+update compose correctly."""
    cfg = M.PRESETS["tiny"]
    flat = M.init_params(cfg, np.array([5], np.int32))
    glob = flat
    x, y = batch(cfg, 5)
    lr, mu = jnp.array([0.05]), jnp.array([0.01])
    first = None
    for _ in range(10):
        flat, loss, _ = M.train_step(cfg, flat, glob, x, y, lr, mu)
        if first is None:
            first = float(loss[0])
    assert float(loss[0]) < first * 0.9
