"""L1 correctness: every Pallas kernel against the pure-jnp oracle.

Hypothesis sweeps shapes (including prime sizes that force non-default
block shapes) and dtypes; assert_allclose against ref.py is THE core
correctness signal for the compute layer.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile import kernels
from compile.kernels import ref

SETTINGS = dict(max_examples=25, deadline=None)


def rngs(seed, *shapes, dtype=jnp.float32):
    key = jax.random.PRNGKey(seed)
    keys = jax.random.split(key, len(shapes))
    return [jax.random.normal(k, s, dtype=dtype) for k, s in zip(keys, shapes)]


dims = st.integers(min_value=1, max_value=67)


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(m=dims, k=dims, n=dims, seed=st.integers(0, 2**31 - 1),
       bias=st.booleans(), relu=st.booleans())
def test_matmul_matches_ref(m, k, n, seed, bias, relu):
    x, w = rngs(seed, (m, k), (k, n))
    b = rngs(seed + 1, (n,))[0] if bias else None
    out = kernels.matmul(x, w, bias=b, relu=relu)
    expect = ref.matmul(x, w, bias=b, relu=relu)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


@settings(**SETTINGS)
@given(seed=st.integers(0, 2**31 - 1),
       bm=st.integers(1, 16), bn=st.integers(1, 16), bk=st.integers(1, 16))
def test_matmul_block_overrides(seed, bm, bn, bk):
    """Any requested tile size must give identical numerics (blocks only
    change the schedule, never the math)."""
    x, w, b = rngs(seed, (16, 16), (16, 16), (16,))
    out = kernels.matmul(x, w, bias=b, relu=True, bm=bm, bn=bn, bk=bk)
    expect = ref.matmul(x, w, bias=b, relu=True)
    np.testing.assert_allclose(out, expect, rtol=1e-5, atol=1e-5)


def test_matmul_bf16():
    x, w = rngs(7, (32, 48), (48, 24), dtype=jnp.bfloat16)
    out = kernels.matmul(x, w)
    expect = ref.matmul(x, w)
    np.testing.assert_allclose(
        out.astype(np.float32), expect.astype(np.float32), rtol=5e-2, atol=5e-2
    )


def test_matmul_shape_mismatch_raises():
    x, w = rngs(0, (4, 5), (6, 7))
    with pytest.raises(AssertionError):
        kernels.matmul(x, w)


# ---------------------------------------------------------------------------
# relu_grad / dense VJP
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(m=dims, n=dims, seed=st.integers(0, 2**31 - 1))
def test_relu_grad_matches_ref(m, n, seed):
    g, y = rngs(seed, (m, n), (m, n))
    np.testing.assert_allclose(
        kernels.relu_grad(g, y), ref.relu_grad(g, y), rtol=1e-6
    )


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 2**31 - 1), relu=st.booleans(),
       m=st.integers(1, 9), k=st.integers(1, 9), n=st.integers(1, 9))
def test_dense_vjp_matches_autodiff_of_ref(seed, relu, m, k, n):
    """The custom VJP (Pallas bwd kernels) must equal jax.grad through the
    reference forward."""
    x, w, b, ct = rngs(seed, (m, k), (k, n), (n,), (m, n))

    def f_pallas(x, w, b):
        return jnp.sum(kernels.dense(x, w, b, relu) * ct)

    def f_ref(x, w, b):
        return jnp.sum(ref.matmul(x, w, bias=b, relu=relu) * ct)

    gp = jax.grad(f_pallas, argnums=(0, 1, 2))(x, w, b)
    gr = jax.grad(f_ref, argnums=(0, 1, 2))(x, w, b)
    for a, e in zip(gp, gr):
        np.testing.assert_allclose(a, e, rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# fedprox_step
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(p=st.integers(1, 5000), seed=st.integers(0, 2**31 - 1),
       lr=st.floats(0.0, 1.0), mu=st.floats(0.0, 1.0))
def test_fedprox_matches_ref(p, seed, lr, mu):
    pv, p0, g = rngs(seed, (p,), (p,), (p,))
    np.testing.assert_allclose(
        kernels.fedprox_step(pv, p0, g, lr, mu),
        ref.fedprox_step(pv, p0, g, lr, mu),
        rtol=1e-5, atol=1e-6,
    )


def test_fedprox_zero_lr_is_identity():
    pv, p0, g = rngs(3, (257,), (257,), (257,))
    np.testing.assert_allclose(kernels.fedprox_step(pv, p0, g, 0.0, 0.5), pv)


def test_fedprox_mu_zero_is_sgd():
    pv, p0, g = rngs(4, (64,), (64,), (64,))
    np.testing.assert_allclose(
        kernels.fedprox_step(pv, p0, g, 0.1, 0.0), pv - 0.1 * g, rtol=1e-6
    )


def test_fedprox_pulls_toward_global():
    """With g=0, the update must move p strictly toward p0."""
    pv, p0 = rngs(5, (128,), (128,))
    out = kernels.fedprox_step(pv, p0, jnp.zeros_like(pv), 0.5, 0.3)
    assert float(jnp.linalg.norm(out - p0)) < float(jnp.linalg.norm(pv - p0))


# ---------------------------------------------------------------------------
# weighted_sum (aggregation)
# ---------------------------------------------------------------------------

@settings(**SETTINGS)
@given(k=st.integers(1, 20), p=st.integers(1, 3000),
       seed=st.integers(0, 2**31 - 1))
def test_weighted_sum_matches_ref(k, p, seed):
    u = rngs(seed, (k, p))[0]
    w = jnp.abs(rngs(seed + 1, (k,))[0])
    np.testing.assert_allclose(
        kernels.weighted_sum(u, w), ref.weighted_sum(u, w),
        rtol=1e-4, atol=1e-4,
    )


def test_weighted_sum_zero_padding_invariant():
    """Appending zero-weight rows must not change the result — this is what
    lets the server use a fixed-K aggregation artifact."""
    u = rngs(9, (4, 500))[0]
    w = jnp.array([0.3, 0.5, 0.1, 0.7])
    base = kernels.weighted_sum(u, w)
    pad_u = jnp.concatenate([u, rngs(10, (3, 500))[0]])
    pad_w = jnp.concatenate([w, jnp.zeros(3)])
    np.testing.assert_allclose(
        kernels.weighted_sum(pad_u, pad_w), base, rtol=1e-5, atol=1e-5
    )


def test_weighted_sum_one_hot_selects_row():
    u = rngs(11, (6, 100))[0]
    w = jnp.zeros(6).at[2].set(1.0)
    np.testing.assert_allclose(kernels.weighted_sum(u, w), u[2], rtol=1e-6)
