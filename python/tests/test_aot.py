"""AOT path: the lowered HLO text must be well-formed and the manifest must
agree with the model config. (The Rust integration test then loads these
artifacts through PJRT and re-validates numerics end to end.)"""

import json
import os

import numpy as np
import pytest

from compile import aot, model as M


@pytest.fixture(scope="module")
def tiny_artifacts(tmp_path_factory):
    out = tmp_path_factory.mktemp("artifacts")
    aot.emit(str(out), ["tiny"])
    return str(out)


def test_emits_all_entry_points(tiny_artifacts):
    for fn in ["init", "train_step", "eval_step", "aggregate"]:
        path = os.path.join(tiny_artifacts, f"tiny_{fn}.hlo.txt")
        assert os.path.exists(path), path
        text = open(path).read()
        assert "ENTRY" in text, f"{fn}: no ENTRY computation"
        assert "HloModule" in text


def test_manifest_consistent(tiny_artifacts):
    cfg = M.PRESETS["tiny"]
    man = json.load(open(os.path.join(tiny_artifacts, "tiny_manifest.json")))
    assert man["param_count"] == cfg.param_count
    assert man["batch_size"] == cfg.batch_size
    assert man["agg_k"] == cfg.agg_k
    eps = man["entry_points"]
    P, B, D, K = (cfg.param_count, cfg.batch_size, cfg.input_dim, cfg.agg_k)
    assert eps["train_step"]["inputs"][0] == ["f32", [P]]
    assert eps["train_step"]["inputs"][2] == ["f32", [B, D]]
    assert eps["aggregate"]["inputs"][0] == ["f32", [K, P]]


def test_train_step_hlo_mentions_all_params(tiny_artifacts):
    """The lowered module must take exactly 6 parameters (params, global,
    x, y, lr, mu) — a rust-side contract."""
    text = open(os.path.join(tiny_artifacts, "tiny_train_step.hlo.txt")).read()
    entry = text[text.index("ENTRY"):]
    header = entry[:entry.index("\n")]
    # count "parameter" declarations in the whole entry computation instead
    n_params = entry.count("parameter(")
    assert n_params == 6, header


def test_hlo_has_no_64bit_ids(tiny_artifacts):
    """Text interchange exists precisely because serialized protos carry
    64-bit ids; the text itself must parse as ASCII and stay modest."""
    for fn in ["init", "train_step", "eval_step", "aggregate"]:
        text = open(os.path.join(tiny_artifacts, f"tiny_{fn}.hlo.txt")).read()
        text.encode("ascii")  # raises on surprise bytes
        assert len(text) < 5_000_000
