"""The L1 perf model must agree with the actual kernel block picking and
stay within hardware envelopes for every preset."""

import pytest

from compile import model as M
from compile import perf_analysis as P
from compile.kernels.matmul import _pick_block


@pytest.mark.parametrize("preset", sorted(M.PRESETS))
def test_vmem_always_fits(preset):
    cfg = M.PRESETS[preset]
    for r in P.preset_reports(cfg):
        assert r.vmem_frac < 0.5, f"{r.name} would not double-buffer: {r.vmem_frac}"
        assert r.bm <= r.m and r.bn <= r.n and r.bk <= r.k


def test_blocks_match_kernel_picker():
    r = P.analyze_matmul("x", 16, 256, 128)
    assert r.bm == _pick_block(16, 128)
    assert r.bk == _pick_block(256, 128)
    assert r.bn == _pick_block(128, 128)


def test_mxu_efficiency_monotone_in_tile_size():
    small = P.analyze_matmul("s", 8, 8, 8)
    big = P.analyze_matmul("b", 128, 128, 128)
    assert big.mxu_tile_eff == 1.0
    assert small.mxu_tile_eff < big.mxu_tile_eff


def test_arithmetic_intensity_increases_with_reuse():
    # bigger N means each x-tile is reused across more output tiles only if
    # bn < n; at fixed tiles, larger matmuls amortise output traffic
    low = P.analyze_matmul("low", 16, 32, 32)
    high = P.analyze_matmul("high", 128, 128, 128)
    assert high.arithmetic_intensity > low.arithmetic_intensity


def test_batch16_mlps_are_bandwidth_bound():
    # honest negative result: at B=16 the fwd matmuls of our presets are
    # bandwidth-bound on TPUv4 (documented in DESIGN.md §Perf)
    cfg = M.PRESETS["vision"]
    fwd = [r for r in P.preset_reports(cfg) if "fwd" in r.name]
    assert any(not r.compute_bound for r in fwd)
